//! Per-request spans: where a request's wall time went, phase by phase.
//!
//! A [`Span`] is a small value the engine threads through one request's
//! dispatch. Each layer that does recognizable work wraps it in
//! [`Span::time`] (or reports a pre-measured duration via [`Span::add`]),
//! attributing the elapsed time to one of the fixed [`Phase`]s:
//!
//! `parse → cache_lookup → execute → compile → replay → render`
//!
//! The wire transport owns `parse`/`render`; the engine owns the middle
//! four. Phases are *disjoint sub-intervals* of the span's wall time, so
//! for every finished [`SpanRecord`] the sum of phase nanos is ≤ the
//! wall nanos — a structural invariant the obs proptest pins.
//!
//! **Zero-cost when disabled**: a span built with recording off carries
//! no `Instant` and [`Span::time`] degenerates to calling the closure —
//! no clock reads, no arithmetic, and [`Span::finish`] records nothing.

use std::time::{Duration, Instant};

/// Number of span phases (the length of [`Phase::ALL`]).
pub const PHASES: usize = 6;

/// One phase of a request's lifecycle. Discriminants index the
/// fixed-size phase arrays in [`Span`] and [`SpanRecord`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Wire-level request decoding (JSON line → typed `Request`).
    Parse,
    /// Trace-cache lookup for the request's trace key.
    CacheLookup,
    /// Functional execution (trace capture) on a cache miss.
    Execute,
    /// Trace compilation into per-op gather rows.
    Compile,
    /// Timing replay (scalar walk or lane-packed batch).
    Replay,
    /// Response rendering/encoding back onto the wire.
    Render,
}

impl Phase {
    /// Every phase, in lifecycle order.
    pub const ALL: [Phase; PHASES] = [
        Phase::Parse,
        Phase::CacheLookup,
        Phase::Execute,
        Phase::Compile,
        Phase::Replay,
        Phase::Render,
    ];

    /// Stable wire/text name.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Parse => "parse",
            Phase::CacheLookup => "cache_lookup",
            Phase::Execute => "execute",
            Phase::Compile => "compile",
            Phase::Replay => "replay",
            Phase::Render => "render",
        }
    }
}

/// An in-flight request span. Created by
/// [`MetricsRegistry::span`](super::metrics::MetricsRegistry::span)
/// (enabled iff recording is on) and finished back into the registry's
/// ring by
/// [`MetricsRegistry::finish_span`](super::metrics::MetricsRegistry::finish_span).
#[derive(Debug)]
pub struct Span {
    op: &'static str,
    /// `Some` ⇔ recording enabled; doubles as the wall-clock anchor.
    started: Option<Instant>,
    phase_nanos: [u64; PHASES],
}

impl Span {
    /// A span for one request; `enabled = false` yields the zero-cost
    /// variant (no clock is ever read).
    pub fn new(op: &'static str, enabled: bool) -> Self {
        Self { op, started: enabled.then(Instant::now), phase_nanos: [0; PHASES] }
    }

    /// The zero-cost variant, for callers without a registry.
    pub fn disabled(op: &'static str) -> Self {
        Self::new(op, false)
    }

    pub fn enabled(&self) -> bool {
        self.started.is_some()
    }

    pub fn op(&self) -> &'static str {
        self.op
    }

    /// Relabel the span once the op is known (the wire transport opens
    /// the span before the line is parsed).
    pub fn set_op(&mut self, op: &'static str) {
        self.op = op;
    }

    /// Run `f`, attributing its elapsed time to `phase`. When the span
    /// is disabled this is exactly `f()`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        if self.started.is_none() {
            return f();
        }
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    /// Attribute an externally measured duration to `phase` (used by
    /// layers that time their own sub-phases, e.g. the sweep runner's
    /// capture/compile/replay split).
    pub fn add(&mut self, phase: Phase, d: Duration) {
        if self.started.is_some() {
            self.phase_nanos[phase as usize] =
                self.phase_nanos[phase as usize].saturating_add(d.as_nanos() as u64);
        }
    }

    /// Close the span. `None` when recording was disabled — nothing is
    /// recorded, pinned by the obs disabled-recording test.
    pub fn finish(self) -> Option<SpanRecord> {
        let started = self.started?;
        Some(SpanRecord {
            op: self.op,
            wall_nanos: started.elapsed().as_nanos() as u64,
            phase_nanos: self.phase_nanos,
        })
    }
}

/// A finished span, as stored in the registry's ring buffer.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// The request's wire op name (`"run"`, `"sweep"`, `"batch"`, …).
    pub op: &'static str,
    /// Wall time from span creation to finish.
    pub wall_nanos: u64,
    /// Per-phase attributed time, indexed by `Phase as usize`.
    pub phase_nanos: [u64; PHASES],
}

impl SpanRecord {
    /// Total attributed time; ≤ [`Self::wall_nanos`] by construction
    /// (phases are disjoint sub-intervals of the span's lifetime).
    pub fn phase_sum_nanos(&self) -> u64 {
        self.phase_nanos.iter().sum()
    }
}
