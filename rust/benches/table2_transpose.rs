//! Bench: regenerate Table II (transpose profiling over 8 memory
//! architectures × 3 sizes) and time each simulated cell — the
//! simulator-throughput numbers feed EXPERIMENTS.md §Perf.

use soft_simt::benchkit::{fmt_duration, Bencher};
use soft_simt::coordinator::job::BenchJob;
use soft_simt::coordinator::{report, runner::SweepRunner};
use soft_simt::mem::arch::MemoryArchKind;

fn main() {
    // The table itself.
    let jobs: Vec<BenchJob> = [32u32, 64, 128]
        .iter()
        .flat_map(|n| {
            MemoryArchKind::table2_eight()
                .into_iter()
                .map(move |arch| BenchJob::new(format!("transpose{n}"), arch))
        })
        .collect();
    let results = SweepRunner::default().run(&jobs).expect("sweep");
    println!("{}", report::render_table2(&results));

    // Simulator wall-clock per cell (fast-timing path).
    let mut b = Bencher::new(2, 10);
    for arch in [
        MemoryArchKind::mp_4r1w(),
        MemoryArchKind::banked(16),
        MemoryArchKind::banked_offset(16),
    ] {
        for n in [32u32, 128] {
            let job = BenchJob::new(format!("transpose{n}"), arch);
            let s = b.bench(format!("sim transpose{n} on {arch}"), || {
                job.run().unwrap().report.total_cycles()
            });
            let cycles = job.run().unwrap().report.total_cycles();
            println!(
                "{}  ({:.1} Msim-cycles/s)",
                s.line(),
                cycles as f64 / s.median().as_secs_f64() / 1e6
            );
        }
    }
    println!("\nfull 24-cell sweep:");
    let mut b2 = Bencher::new(1, 5);
    let s = b2.bench("table2_sweep_total", || {
        SweepRunner::default().run(&jobs).unwrap().len()
    });
    println!("{}  ({} cells)", s.line(), jobs.len());
    let _ = fmt_duration;
}
