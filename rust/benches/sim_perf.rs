//! Bench: simulator micro-benchmarks — the §Perf optimization targets.
//!
//! Measures the L3 hot paths in isolation (conflict analysis, arbiter
//! stepping, exact-vs-fast banked ops, whole-machine throughput) so the
//! before/after rows of EXPERIMENTS.md §Perf come from one place.

use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::job::BenchJob;
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::mem::arbiter::BankArbiters;
use soft_simt::mem::arch::{MemoryArchKind, SharedMemory};
use soft_simt::mem::banked::{BankedMemory, TimingMode};
use soft_simt::mem::conflict::{analyze, max_conflicts};
use soft_simt::mem::mapping::{BankMap, BankMapping};
use soft_simt::mem::{FULL_MASK, LANES};
use soft_simt::util::XorShift64;

fn random_ops(n: usize, seed: u64) -> Vec<[u32; LANES]> {
    let mut rng = XorShift64::new(seed);
    (0..n)
        .map(|_| {
            let mut a = [0u32; LANES];
            for x in a.iter_mut() {
                *x = rng.below(1 << 14);
            }
            a
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new(3, 15);
    let ops = random_ops(10_000, 42);
    let map = BankMap::new(16, BankMapping::Lsb);

    // Conflict-analysis hot path: full analysis vs closed-form max.
    b.bench("conflict_analyze_10k_ops", || {
        ops.iter().map(|op| analyze(op, FULL_MASK, &map).max_conflicts).sum::<u32>()
    });
    b.bench("conflict_max_fast_10k_ops", || {
        ops.iter().map(|op| max_conflicts(op, FULL_MASK, &map)).sum::<u32>()
    });

    // Arbiter scheduling.
    b.bench("arbiter_schedule_10k_ops", || {
        ops.iter()
            .map(|op| {
                let info = analyze(op, FULL_MASK, &map);
                BankArbiters::load(&info.columns).run().len()
            })
            .sum::<usize>()
    });

    // Banked memory: exact (arbiter-stepped) vs fast read ops.
    let mut exact = BankedMemory::new(16_384, 16, BankMapping::Lsb);
    let mut fast = BankedMemory::new(16_384, 16, BankMapping::Lsb).with_mode(TimingMode::Fast);
    b.bench("banked_read_exact_10k_ops", || {
        ops.iter().map(|op| exact.read_op(op, FULL_MASK).cycles).sum::<u32>()
    });
    b.bench("banked_read_fast_10k_ops", || {
        ops.iter().map(|op| fast.read_op(op, FULL_MASK).cycles).sum::<u32>()
    });

    // Whole-machine throughput: the radix-16 FFT cell, exact vs fast —
    // both as the full coordinator cell (codegen + twiddle table + sim)
    // and as simulation only (machine + program prebuilt; the §Perf
    // simulator-throughput number).
    for (label, fast_timing) in [("exact", false), ("fast", true)] {
        let mut job = BenchJob::new("fft4096r16", MemoryArchKind::banked_offset(16));
        job.fast_timing = fast_timing;
        let cycles = job.run().unwrap().report.total_cycles();
        let s = b.bench(format!("machine_fft_r16_{label}_cell"), || {
            job.run().unwrap().report.total_cycles()
        });
        println!(
            "{}  ({:.1} Msim-cycles/s incl. codegen)",
            s.line(),
            cycles as f64 / s.median().as_secs_f64() / 1e6
        );
    }
    {
        use soft_simt::programs::fft::fft_program;
        use soft_simt::sim::config::MachineConfig;
        use soft_simt::sim::machine::Machine;
        let (plan, program) = fft_program(16);
        for (label, fast) in [("exact", false), ("fast", true)] {
            let mut cfg = MachineConfig::for_arch(MemoryArchKind::banked_offset(16))
                .with_mem_words(plan.mem_words())
                .with_tw_region(plan.tw_region());
            if fast {
                cfg = cfg.with_fast_timing();
            }
            let mut machine = Machine::new(cfg);
            let mut rng = XorShift64::new(1);
            let data = rng.f32_vec(2 * plan.n as usize);
            machine.load_f32_image(plan.data_base, &data);
            machine.load_f32_image(plan.tw_base, &plan.twiddles);
            let cycles = machine.run_program(&program).unwrap().total_cycles();
            let s = b.bench(format!("machine_fft_r16_{label}_sim_only"), || {
                machine.run_program(&program).unwrap().total_cycles()
            });
            println!(
                "{}  ({:.1} Msim-cycles/s sim-only)",
                s.line(),
                cycles as f64 / s.median().as_secs_f64() / 1e6
            );
        }
    }

    // Full 51-cell paper sweep (the end-to-end driver's core), per-cell
    // re-execution vs the trace-cached path the CLI now uses.
    let jobs = BenchJob::paper_sweep();
    let mut b2 = Bencher::new(1, 5);
    let s = b2.bench("paper_sweep_51_cells", || {
        SweepRunner::default().run(&jobs).unwrap().len()
    });
    println!("{}", s.line());
    let s = b2.bench("paper_sweep_51_cells_cached", || {
        SweepRunner::default().run_cached(&jobs).unwrap().len()
    });
    println!("{}", s.line());

    // Sweep throughput: a 9-architecture sweep with and without the
    // trace cache, on one worker so the numbers measure total simulation
    // *work* (the wall-clock win additionally depends on worker count).
    // Emits BENCH_sweep.json so future PRs can track the trajectory.
    let sweep_jobs: Vec<BenchJob> = ["transpose128", "fft4096r8", "fft4096r16"]
        .iter()
        .flat_map(|p| {
            MemoryArchKind::table3_nine()
                .into_iter()
                .map(move |arch| BenchJob::new(p.to_string(), arch))
        })
        .collect();
    let serial = SweepRunner::new(1);
    let mut b3 = Bencher::new(1, 7);
    let base = b3
        .bench("arch_sweep_9x3_reexecute_1w", || serial.run(&sweep_jobs).unwrap().len())
        .clone();
    println!("{}", base.line());
    let cached = b3
        .bench("arch_sweep_9x3_trace_cached_1w", || {
            serial.run_cached(&sweep_jobs).unwrap().len()
        })
        .clone();
    println!("{}", cached.line());
    let speedup = base.median().as_secs_f64() / cached.median().as_secs_f64();
    println!("trace-cache speedup (9 archs, total work): {speedup:.2}x");

    // The compiled batch replayer in isolation: the same 9-arch slate
    // charged from ONE walk of each program's compiled trace vs the
    // legacy per-arch dyn `op_cost` replay of the raw traces (the
    // pre-ISSUE-4 inner loop). Capture/compile cost excluded from both
    // sides — this is the replay-kernel trajectory number.
    use soft_simt::sim::compiled::{replay_many, CompiledTrace};
    use soft_simt::sim::packed::replay_many_packed;
    let nine = MemoryArchKind::table3_nine();
    let traces: Vec<_> = ["transpose128", "fft4096r8", "fft4096r16"]
        .iter()
        .map(|p| {
            let job = BenchJob::new(p.to_string(), MemoryArchKind::banked(16));
            job.capture_trace().unwrap()
        })
        .collect();
    let replay_jobs: Vec<Vec<BenchJob>> = ["transpose128", "fft4096r8", "fft4096r16"]
        .iter()
        .map(|p| nine.iter().map(|&a| BenchJob::new(p.to_string(), a)).collect())
        .collect();
    let dyn_s = b3
        .bench("replay_9archs_x3_dyn_op_cost", || {
            traces
                .iter()
                .zip(&replay_jobs)
                .flat_map(|(t, jobs)| jobs.iter().map(move |j| j.replay_trace(t)))
                .map(|r| r.unwrap().report.total_cycles())
                .sum::<u64>()
        })
        .clone();
    println!("{}", dyn_s.line());
    let compiled: Vec<CompiledTrace> = traces.iter().map(CompiledTrace::compile).collect();
    let batched = b3
        .bench("replay_9archs_x3_compiled_batched", || {
            compiled
                .iter()
                .flat_map(|ct| replay_many(ct, &nine, u64::MAX))
                .map(|r| r.unwrap().total_cycles())
                .sum::<u64>()
        })
        .clone();
    println!("{}", batched.line());
    let batch_speedup = dyn_s.median().as_secs_f64() / batched.median().as_secs_f64();
    println!("compiled batch replay speedup (9 archs × 3 programs): {batch_speedup:.2}x");

    // The ISSUE-6 lane-packed kernel on the same slate: 8 architectures
    // advance per gather row, costs pre-resolved into dense tables.
    // `simd_speedup` is the lane-packed vs scalar `replay_many` ratio —
    // a pure kernel-shape number, independent of machine speed, which
    // is why CI gates it with an absolute floor rather than a baseline.
    let packed = b3
        .bench("replay_9archs_x3_lane_packed", || {
            compiled
                .iter()
                .flat_map(|ct| replay_many_packed(ct, &nine, u64::MAX))
                .map(|r| r.unwrap().total_cycles())
                .sum::<u64>()
        })
        .clone();
    println!("{}", packed.line());
    let simd_speedup = batched.median().as_secs_f64() / packed.median().as_secs_f64();
    println!("lane-packed replay speedup over scalar replay_many: {simd_speedup:.2}x");

    // The same packed slate with telemetry on: the counted driver plus
    // the per-call registry flush the sweep runner performs (local
    // tallies, a handful of atomics per *call*, never per step). CI
    // bounds `instrumented_overhead_pct` with an absolute ceiling, so
    // observability can never quietly tax the replay hot path.
    use soft_simt::obs::{Counter, Hist, MetricsRegistry};
    use soft_simt::sim::packed::{replay_many_packed_counted, ReplayTally};
    let metrics = MetricsRegistry::new();
    let instrumented = b3
        .bench("replay_9archs_x3_lane_packed_instrumented", || {
            let mut cycles = 0u64;
            for ct in &compiled {
                let t0 = std::time::Instant::now();
                let (reports, tally): (Vec<_>, ReplayTally) =
                    replay_many_packed_counted(ct, &nine, u64::MAX);
                metrics.add(Counter::ReplayPackedInvocations, tally.invocations);
                metrics.add(Counter::ReplayPackedChunks, tally.chunks);
                metrics.add(Counter::ReplayPackedLanesUsed, tally.lanes_used);
                metrics.add(Counter::ReplayPackedLaneSlots, tally.lane_slots);
                metrics.add(Counter::ReplayWavefrontSegments, tally.segments);
                let stalls = reports
                    .iter()
                    .filter_map(|r| r.as_ref().ok())
                    .map(|r| r.stats.wbuf_stall_cycles)
                    .sum::<u64>();
                metrics.add(Counter::ReplayWbufStallCycles, stalls);
                metrics.observe(Hist::ReplayMicros, t0.elapsed().as_micros() as u64);
                cycles += reports.into_iter().map(|r| r.unwrap().total_cycles()).sum::<u64>();
            }
            cycles
        })
        .clone();
    println!("{}", instrumented.line());
    let instrumented_overhead_pct = (instrumented.median().as_secs_f64()
        / packed.median().as_secs_f64()
        - 1.0)
        * 100.0;
    println!("instrumented packed replay overhead: {instrumented_overhead_pct:.2}%");

    // The PR-9 divergent kernels on the lane-packed replayer: their
    // traces carry non-full lane masks (owner-predicated
    // compare-exchange stages; skewed per-lane row loops), so these two
    // medians track the masked-popcount path per family.
    let div_compiled: Vec<CompiledTrace> = ["bitonic1024", "spmv1024"]
        .iter()
        .map(|p| {
            let job = BenchJob::new(p.to_string(), MemoryArchKind::banked(16));
            CompiledTrace::compile(&job.capture_trace().unwrap())
        })
        .collect();
    let bitonic = b3
        .bench("replay_9archs_bitonic1024_lane_packed", || {
            replay_many_packed(&div_compiled[0], &nine, u64::MAX)
                .into_iter()
                .map(|r| r.unwrap().total_cycles())
                .sum::<u64>()
        })
        .clone();
    println!("{}", bitonic.line());
    let spmv = b3
        .bench("replay_9archs_spmv1024_lane_packed", || {
            replay_many_packed(&div_compiled[1], &nine, u64::MAX)
                .into_iter()
                .map(|r| r.unwrap().total_cycles())
                .sum::<u64>()
        })
        .clone();
    println!("{}", spmv.line());

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\n  \"bench\": \"arch_sweep_9x3\",\n  \"unix_time\": {unix_time},\n  \
         \"cells\": {cells},\n  \"programs\": 3,\n  \"archs\": 9,\n  \"workers\": 1,\n  \
         \"reexecute_median_ms\": {base_ms:.3},\n  \"trace_cached_median_ms\": {cached_ms:.3},\n  \
         \"speedup\": {speedup:.3},\n  \
         \"replay_dyn_median_ms\": {dyn_ms:.3},\n  \
         \"replay_batched_median_ms\": {batched_ms:.3},\n  \
         \"batch_speedup\": {batch_speedup:.3},\n  \
         \"replay_packed_median_ms\": {packed_ms:.3},\n  \
         \"simd_speedup\": {simd_speedup:.3},\n  \
         \"replay_packed_instrumented_median_ms\": {instr_ms:.3},\n  \
         \"instrumented_overhead_pct\": {instrumented_overhead_pct:.3},\n  \
         \"bitonic_replay_median_ms\": {bitonic_ms:.3},\n  \
         \"spmv_replay_median_ms\": {spmv_ms:.3}\n}}\n",
        cells = sweep_jobs.len(),
        base_ms = base.median().as_secs_f64() * 1e3,
        cached_ms = cached.median().as_secs_f64() * 1e3,
        dyn_ms = dyn_s.median().as_secs_f64() * 1e3,
        batched_ms = batched.median().as_secs_f64() * 1e3,
        packed_ms = packed.median().as_secs_f64() * 1e3,
        instr_ms = instrumented.median().as_secs_f64() * 1e3,
        bitonic_ms = bitonic.median().as_secs_f64() * 1e3,
        spmv_ms = spmv.median().as_secs_f64() * 1e3,
    );
    match std::fs::write("BENCH_sweep.json", &json) {
        Ok(()) => println!("wrote BENCH_sweep.json"),
        Err(e) => eprintln!("could not write BENCH_sweep.json: {e}"),
    }

    print!("{}", b.report());
}
