//! Bench: regenerate Fig. 9 (cost vs normalized radix-16 FFT performance
//! at 64/112/168/224 KB) — the paper's §VI "what is the best memory"
//! figure — plus the perf-per-area ranking its prose draws from it.

use soft_simt::area::fig9::{perf_per_area, SIZES_KB};
use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::job::BenchJob;
use soft_simt::coordinator::{report, runner::SweepRunner};
use soft_simt::mem::arch::MemoryArchKind;

fn main() {
    let jobs: Vec<BenchJob> = MemoryArchKind::table3_nine()
        .into_iter()
        .map(|arch| BenchJob::new("fft4096r16", arch))
        .collect();
    let results = SweepRunner::default().run(&jobs).expect("sweep");
    println!("{}", report::render_fig9(&results));

    // Perf-per-area ranking at each size (the "smaller banked memories
    // are more efficient" observation).
    let points = report::fig9_points(&results);
    for &kb in &SIZES_KB {
        let mut rank: Vec<(String, f64)> = points
            .iter()
            .filter(|p| p.size_kb == kb)
            .filter_map(|p| perf_per_area(p).map(|v| (p.arch.label(), v)))
            .collect();
        rank.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        println!("\nperf/area at {kb} KB (higher is better):");
        for (label, v) in rank {
            println!("  {label:20} {v:.3}");
        }
    }

    let mut b = Bencher::new(1, 5);
    let s = b.bench("fig9_sweep_and_render", || {
        let r = SweepRunner::default().run(&jobs).unwrap();
        report::render_fig9(&r).len()
    });
    println!("\n{}", s.line());
}
