//! Bench: regenerate Table I (resource counts + Fmax model) and time the
//! area-model queries (they sit on the Fig. 9 sweep path).

use soft_simt::area::footprint;
use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::report;
use soft_simt::mem::arch::MemoryArchKind;

fn main() {
    println!("{}", report::render_table1());

    let mut b = Bencher::new(3, 20);
    b.bench("table1_render", report::render_table1);
    b.bench("footprint_grid_all_archs", || {
        let mut acc = 0u64;
        for arch in MemoryArchKind::table3_nine() {
            for kb in [64u32, 112, 168, 224, 448] {
                if let Some(f) = footprint::processor_footprint(arch, kb) {
                    acc += f.total_alms() as u64;
                }
            }
        }
        acc
    });
    print!("{}", b.report());
}
