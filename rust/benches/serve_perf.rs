//! Bench: service-engine throughput on a mixed batch (repeat runs + the
//! paper sweep + a design-space exploration), plus a **saturation mode**
//! — N in-process client sessions (1/4/16) hammering one warm shared
//! engine with single requests, reporting per-request p50/p99 latency
//! and aggregate throughput per client count. Emits `BENCH_serve.json`
//! so CI can track the service layer's trajectory next to
//! `BENCH_sweep.json` and `BENCH_explore.json`.
//!
//! The saturation section also asserts the ISSUE's warm-path guarantee:
//! the whole measured window takes **zero** trace-store shard write
//! locks (`store.shard_write_locks` is flat), i.e. concurrent warm
//! reads really are read-lock-only.

use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::obs::{Counter, Histogram};
use soft_simt::server::Session;
use soft_simt::service::{ExploreStrategy, Request, SimtEngine};
use std::sync::Arc;
use std::time::Instant;

/// Concurrency levels for the saturation mode.
const CLIENT_COUNTS: [usize; 3] = [1, 4, 16];
/// Warm requests each client issues per saturation point.
const REQUESTS_PER_CLIENT: usize = 256;

struct SaturationPoint {
    clients: usize,
    p50_us: u64,
    p99_us: u64,
    throughput_rps: f64,
}

/// One saturation point: `clients` sessions over the shared warm
/// engine, each issuing [`REQUESTS_PER_CLIENT`] single `Run` requests;
/// per-request latency lands in one shared lock-free histogram.
fn saturate(engine: &Arc<SimtEngine>, clients: usize) -> SaturationPoint {
    let hist = Histogram::new();
    let archs = MemoryArchKind::table3_nine();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let engine = Arc::clone(engine);
            let hist = &hist;
            let archs = &archs;
            scope.spawn(move || {
                let session = Session::new(engine);
                for k in 0..REQUESTS_PER_CLIENT {
                    let program =
                        if (c + k) % 2 == 0 { "transpose32" } else { "transpose64" };
                    let req = Request::Run {
                        program: program.into(),
                        mem: archs[(c + k) % archs.len()],
                    };
                    let t = Instant::now();
                    session.handle(&req).expect("warm run");
                    hist.record(t.elapsed().as_micros() as u64);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let counts = hist.snapshot();
    SaturationPoint {
        clients,
        p50_us: counts.percentile(0.50),
        p99_us: counts.percentile(0.99),
        throughput_rps: (clients * REQUESTS_PER_CLIENT) as f64 / wall,
    }
}

/// The measured unit: a session-shaped batch — one sweep, one explore,
/// twenty repeat runs across memories.
fn mixed_batch() -> Vec<Request> {
    let mut batch = vec![
        Request::Sweep { all: false },
        Request::Explore {
            program: "transpose32".into(),
            strategy: ExploreStrategy::Halving,
        },
    ];
    let archs = MemoryArchKind::table3_nine();
    for i in 0..20 {
        batch.push(Request::Run {
            program: if i % 2 == 0 { "transpose32".into() } else { "transpose64".into() },
            mem: archs[i % archs.len()],
        });
    }
    batch
}

fn main() {
    let batch = mixed_batch();
    let runner_workers = SweepRunner::default().workers();
    println!(
        "serve bench: mixed batch of {} requests (1 sweep + 1 explore + {} runs), {} workers",
        batch.len(),
        batch.len() - 2,
        runner_workers
    );

    let mut b = Bencher::new(1, 7);

    // Cold session: fresh engine per iteration — every trace captured.
    let cold = b
        .bench("serve_mixed_batch_cold_engine", || {
            let engine = SimtEngine::new();
            let responses = engine.handle_batch(&batch);
            assert!(responses.iter().all(Result::is_ok));
            engine.functional_executions()
        })
        .clone();
    let cold_engine = SimtEngine::new();
    cold_engine.handle_batch(&batch).iter().for_each(|r| assert!(r.is_ok()));
    let executions = cold_engine.functional_executions();
    println!("{}  ({} functional executions per cold batch)", cold.line(), executions);

    // Warm session: one long-lived engine, repeat batches replay only.
    let engine = SimtEngine::new();
    engine.handle_batch(&batch).iter().for_each(|r| assert!(r.is_ok()));
    let warm_base = engine.functional_executions();
    let warm = b
        .bench("serve_mixed_batch_warm_engine", || {
            let responses = engine.handle_batch(&batch);
            assert!(responses.iter().all(Result::is_ok));
            responses.len()
        })
        .clone();
    assert_eq!(
        engine.functional_executions(),
        warm_base,
        "warm batches must not re-execute"
    );
    let warm_rps = batch.len() as f64 / warm.median().as_secs_f64();
    println!("{}  ({:.1} requests/s warm)", warm.line(), warm_rps);

    // Telemetry overhead on the warm path: the warm bench above runs
    // with span recording ON (the engine default); re-run it with
    // recording off to price the spans + clock reads. Counters are
    // always on — they are the part designed to be free. CI bounds the
    // delta with `--ceiling instrumented_overhead_pct=2.0`.
    engine.metrics().set_recording(false);
    let warm_off = b
        .bench("serve_mixed_batch_warm_recording_off", || {
            let responses = engine.handle_batch(&batch);
            assert!(responses.iter().all(Result::is_ok));
            responses.len()
        })
        .clone();
    engine.metrics().set_recording(true);
    let instrumented_overhead_pct =
        (warm.median().as_secs_f64() / warm_off.median().as_secs_f64() - 1.0) * 100.0;
    println!(
        "{}  (span recording overhead {:.2}%)",
        warm_off.line(),
        instrumented_overhead_pct
    );

    // Saturation mode: a dedicated shared engine, warmed so every
    // workload's trace and compiled form already exist — the measured
    // window is pure concurrent warm traffic.
    let shared = Arc::new(SimtEngine::new());
    for arch in MemoryArchKind::table3_nine() {
        for program in ["transpose32", "transpose64"] {
            // Twice per cell: the second run builds the compiled trace.
            for _ in 0..2 {
                shared
                    .handle(&Request::Run { program: program.into(), mem: arch })
                    .expect("warmup run");
            }
        }
    }
    let warm_locks = shared.metrics().get(Counter::StoreShardWriteLocks);
    let mut points = Vec::new();
    for &clients in &CLIENT_COUNTS {
        let p = saturate(&shared, clients);
        println!(
            "saturation c{:<2}  p50 {:>6} us  p99 {:>6} us  {:>9.1} req/s",
            p.clients, p.p50_us, p.p99_us, p.throughput_rps
        );
        points.push(p);
    }
    assert_eq!(
        shared.metrics().get(Counter::StoreShardWriteLocks),
        warm_locks,
        "warm saturation traffic must take no shard write lock"
    );
    println!(
        "shard write locks flat at {} across {} concurrent warm requests",
        warm_locks,
        CLIENT_COUNTS.iter().sum::<usize>() * REQUESTS_PER_CLIENT
    );

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut json = format!(
        "{{\n  \"bench\": \"serve_mixed_batch\",\n  \"unix_time\": {unix_time},\n  \
         \"batch_requests\": {n},\n  \"cold_median_ms\": {cold_ms:.3},\n  \
         \"warm_median_ms\": {warm_ms:.3},\n  \"warm_requests_per_sec\": {warm_rps:.1},\n  \
         \"functional_executions_per_cold_batch\": {executions},\n  \
         \"warm_recording_off_median_ms\": {warm_off_ms:.3},\n  \
         \"instrumented_overhead_pct\": {instrumented_overhead_pct:.3}",
        n = batch.len(),
        cold_ms = cold.median().as_secs_f64() * 1e3,
        warm_ms = warm.median().as_secs_f64() * 1e3,
        warm_off_ms = warm_off.median().as_secs_f64() * 1e3,
    );
    for p in &points {
        json.push_str(&format!(
            ",\n  \"concurrent_c{c}_p50_us\": {p50},\n  \"concurrent_c{c}_p99_us\": {p99},\n  \
             \"concurrent_c{c}_throughput_rps\": {rps:.1}",
            c = p.clients,
            p50 = p.p50_us,
            p99 = p.p99_us,
            rps = p.throughput_rps,
        ));
    }
    json.push_str("\n}\n");
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
    print!("{}", b.report());
}
