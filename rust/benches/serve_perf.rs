//! Bench: service-engine throughput on a mixed batch (repeat runs + the
//! paper sweep + a design-space exploration) — emits `BENCH_serve.json`
//! (requests/sec, functional executions per batch) so CI can track the
//! service layer's trajectory next to `BENCH_sweep.json` and
//! `BENCH_explore.json`.

use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::service::{ExploreStrategy, Request, SimtEngine};

/// The measured unit: a session-shaped batch — one sweep, one explore,
/// twenty repeat runs across memories.
fn mixed_batch() -> Vec<Request> {
    let mut batch = vec![
        Request::Sweep { all: false },
        Request::Explore {
            program: "transpose32".into(),
            strategy: ExploreStrategy::Halving,
        },
    ];
    let archs = MemoryArchKind::table3_nine();
    for i in 0..20 {
        batch.push(Request::Run {
            program: if i % 2 == 0 { "transpose32".into() } else { "transpose64".into() },
            mem: archs[i % archs.len()],
        });
    }
    batch
}

fn main() {
    let batch = mixed_batch();
    let runner_workers = SweepRunner::default().workers();
    println!(
        "serve bench: mixed batch of {} requests (1 sweep + 1 explore + {} runs), {} workers",
        batch.len(),
        batch.len() - 2,
        runner_workers
    );

    let mut b = Bencher::new(1, 7);

    // Cold session: fresh engine per iteration — every trace captured.
    let cold = b
        .bench("serve_mixed_batch_cold_engine", || {
            let engine = SimtEngine::new();
            let responses = engine.handle_batch(&batch);
            assert!(responses.iter().all(Result::is_ok));
            engine.functional_executions()
        })
        .clone();
    let cold_engine = SimtEngine::new();
    cold_engine.handle_batch(&batch).iter().for_each(|r| assert!(r.is_ok()));
    let executions = cold_engine.functional_executions();
    println!("{}  ({} functional executions per cold batch)", cold.line(), executions);

    // Warm session: one long-lived engine, repeat batches replay only.
    let engine = SimtEngine::new();
    engine.handle_batch(&batch).iter().for_each(|r| assert!(r.is_ok()));
    let warm_base = engine.functional_executions();
    let warm = b
        .bench("serve_mixed_batch_warm_engine", || {
            let responses = engine.handle_batch(&batch);
            assert!(responses.iter().all(Result::is_ok));
            responses.len()
        })
        .clone();
    assert_eq!(
        engine.functional_executions(),
        warm_base,
        "warm batches must not re-execute"
    );
    let warm_rps = batch.len() as f64 / warm.median().as_secs_f64();
    println!("{}  ({:.1} requests/s warm)", warm.line(), warm_rps);

    // Telemetry overhead on the warm path: the warm bench above runs
    // with span recording ON (the engine default); re-run it with
    // recording off to price the spans + clock reads. Counters are
    // always on — they are the part designed to be free. CI bounds the
    // delta with `--ceiling instrumented_overhead_pct=2.0`.
    engine.metrics().set_recording(false);
    let warm_off = b
        .bench("serve_mixed_batch_warm_recording_off", || {
            let responses = engine.handle_batch(&batch);
            assert!(responses.iter().all(Result::is_ok));
            responses.len()
        })
        .clone();
    engine.metrics().set_recording(true);
    let instrumented_overhead_pct =
        (warm.median().as_secs_f64() / warm_off.median().as_secs_f64() - 1.0) * 100.0;
    println!(
        "{}  (span recording overhead {:.2}%)",
        warm_off.line(),
        instrumented_overhead_pct
    );

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\n  \"bench\": \"serve_mixed_batch\",\n  \"unix_time\": {unix_time},\n  \
         \"batch_requests\": {n},\n  \"cold_median_ms\": {cold_ms:.3},\n  \
         \"warm_median_ms\": {warm_ms:.3},\n  \"warm_requests_per_sec\": {warm_rps:.1},\n  \
         \"functional_executions_per_cold_batch\": {executions},\n  \
         \"warm_recording_off_median_ms\": {warm_off_ms:.3},\n  \
         \"instrumented_overhead_pct\": {instrumented_overhead_pct:.3}\n}}\n",
        n = batch.len(),
        cold_ms = cold.median().as_secs_f64() * 1e3,
        warm_ms = warm.median().as_secs_f64() * 1e3,
        warm_off_ms = warm_off.median().as_secs_f64() * 1e3,
    );
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
    print!("{}", b.report());
}
