//! Bench: regenerate Table III (FFT profiling over 9 memory architectures
//! × 3 radices) and time the simulated cells.

use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::job::BenchJob;
use soft_simt::coordinator::{report, runner::SweepRunner};
use soft_simt::mem::arch::MemoryArchKind;

fn main() {
    let jobs: Vec<BenchJob> = [4u32, 8, 16]
        .iter()
        .flat_map(|r| {
            MemoryArchKind::table3_nine()
                .into_iter()
                .map(move |arch| BenchJob::new(format!("fft4096r{r}"), arch))
        })
        .collect();
    let results = SweepRunner::default().run(&jobs).expect("sweep");
    println!("{}", report::render_table3(&results));

    let mut b = Bencher::new(2, 8);
    for radix in [4u32, 16] {
        for arch in [MemoryArchKind::mp_4r1w(), MemoryArchKind::banked_offset(16)] {
            let job = BenchJob::new(format!("fft4096r{radix}"), arch);
            let cycles = job.run().unwrap().report.total_cycles();
            let s = b.bench(format!("sim fft r{radix} on {arch}"), || {
                job.run().unwrap().report.total_cycles()
            });
            println!(
                "{}  ({:.1} Msim-cycles/s)",
                s.line(),
                cycles as f64 / s.median().as_secs_f64() / 1e6
            );
        }
    }
    println!("\nfull 27-cell sweep:");
    let mut b2 = Bencher::new(1, 5);
    let s = b2.bench("table3_sweep_total", || {
        SweepRunner::default().run(&jobs).unwrap().len()
    });
    println!("{}  ({} cells)", s.line(), jobs.len());
}
