//! Ablation bench: bank-mapping strategies (LSB vs Offset vs XOR) across
//! every benchmark — the paper's §VII "varying the bank mapping" future
//! work, quantified.
//!
//! Also ablates the §IV-A half-bank split (+2 cycles of bank latency,
//! which the paper reports as having "no material impact").

use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::job::BenchJob;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::mem::mapping::BankMapping;
use soft_simt::programs::library::{program_by_name, program_names};
use soft_simt::sim::config::MachineConfig;
use soft_simt::sim::machine::Machine;
use soft_simt::util::fmt::TextTable;

fn main() {
    // Mapping ablation table.
    let mappings = [BankMapping::Lsb, BankMapping::offset(), BankMapping::Xor];
    let mut t = TextTable::new([
        "program".to_string(),
        "banks".into(),
        "LSB".into(),
        "Offset".into(),
        "XOR".into(),
        "best".into(),
    ]);
    for program in program_names() {
        for banks in [4u32, 8, 16] {
            let mut cells = Vec::new();
            for mapping in mappings {
                let arch = MemoryArchKind::Banked { banks, mapping };
                let r = BenchJob::new(program.as_str(), arch).run().expect("runs");
                cells.push((mapping.label(), r.report.total_cycles()));
            }
            let best = cells.iter().min_by_key(|(_, c)| *c).unwrap();
            t.row([
                program.to_string(),
                banks.to_string(),
                cells[0].1.to_string(),
                cells[1].1.to_string(),
                cells[2].1.to_string(),
                if best.0.is_empty() { "LSB".to_string() } else { best.0.clone() },
            ]);
        }
    }
    println!("Bank-mapping ablation (total cycles; lower is better)\n{}", t.render());

    // Half-bank ablation: the 448 KB node-locked configuration.
    println!("Half-bank split ablation (§IV-A: expect 'no material impact'):");
    for program in ["fft4096r16", "transpose128"] {
        let workload = program_by_name(program).unwrap();
        let mut totals = Vec::new();
        for half in [false, true] {
            let mut cfg = MachineConfig::for_arch(MemoryArchKind::banked_offset(16))
                .with_mem_words(workload.mem_words())
                .with_fast_timing();
            cfg.half_banks = half;
            if let Some(r) = workload.tw_region() {
                cfg = cfg.with_tw_region(r);
            }
            let mut m = Machine::new(cfg);
            workload.load_input(&mut m, 1);
            totals.push(m.run_program(workload.program()).unwrap().total_cycles());
        }
        let delta = 100.0 * (totals[1] as f64 - totals[0] as f64) / totals[0] as f64;
        println!(
            "  {program:14} normal {} vs half-banked {}  ({delta:+.2}%)",
            totals[0], totals[1]
        );
    }

    // Timing.
    let mut b = Bencher::new(1, 5);
    let s = b.bench("mapping_ablation_full_grid", || {
        let mut acc = 0u64;
        for banks in [4u32, 8, 16] {
            for mapping in mappings {
                let arch = MemoryArchKind::Banked { banks, mapping };
                acc += BenchJob::new("transpose32", arch).run().unwrap().report.total_cycles();
            }
        }
        acc
    });
    println!("\n{}", s.line());
}
