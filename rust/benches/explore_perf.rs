//! Bench: design-space explorer throughput on the smallest transpose
//! workload — emits `BENCH_explore.json` (points-evaluated/sec) so CI
//! can track the explorer's trajectory across PRs, next to
//! `BENCH_sweep.json`.

use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::job::{BenchJob, TraceCache};
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::explore::{
    explore, explore_system, DesignSpace, Exhaustive, SearchStrategy, SuccessiveHalving,
    SystemSpace,
};
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::programs::library::program_by_name;
use soft_simt::sim::compiled::{replay_many, CompiledTrace};
use soft_simt::sim::packed::replay_many_packed;

fn main() {
    let program = "transpose32"; // smallest registered transpose workload
    let dataset_kb = program_by_name(program).unwrap().dataset_kb();
    let space = DesignSpace::parametric(dataset_kb);
    let n_points = space.points().len();
    let runner = SweepRunner::default();
    println!(
        "explorer bench: {program}, {n_points} design points, {} architectures, {} workers",
        space.arch_count(),
        runner.workers()
    );

    let mut b = Bencher::new(1, 7);
    let mut summaries = Vec::new();
    let strategies: [(&str, &dyn SearchStrategy); 2] = [
        ("exhaustive", &Exhaustive),
        ("halving", &SuccessiveHalving { min_wave: 8 }),
    ];
    for (name, strategy) in strategies {
        // Cold cache each iteration: the measured unit is capture +
        // full search, the explorer's end-to-end cost.
        let result = {
            let cache = TraceCache::new();
            explore(program, &space, strategy, &runner, &cache).unwrap()
        };
        assert_eq!(result.captures, 1);
        let s = b
            .bench(format!("explore_{program}_{name}_cold"), || {
                let cache = TraceCache::new();
                explore(program, &space, strategy, &runner, &cache).unwrap().points_scored
            })
            .clone();
        let scored_per_sec = result.points_scored as f64 / s.median().as_secs_f64();
        println!(
            "{}  ({} scored, {} culled, {:.0} points-evaluated/s)",
            s.line(),
            result.points_scored,
            result.points_culled,
            scored_per_sec
        );
        summaries.push((name, result, s));
    }

    // ISSUE 10: the system explorer over its parametric space — {1,2,4}
    // cores × {16,32,64} lanes × paper nine × 3 capacities — cold-cache
    // each iteration, the same measured unit as the flat strategies.
    let sys_space = SystemSpace::parametric(dataset_kb);
    let sys_result = {
        let cache = TraceCache::new();
        explore_system(program, &sys_space, &cache).unwrap()
    };
    assert_eq!(sys_result.captures, 1);
    let sys_s = b
        .bench(format!("explore_{program}_system_cold"), || {
            let cache = TraceCache::new();
            explore_system(program, &sys_space, &cache).unwrap().points_scored
        })
        .clone();
    println!(
        "{}  ({} system points, {} system replays)",
        sys_s.line(),
        sys_result.points_scored,
        sys_result.replays
    );

    // The PR's inner-loop win, isolated: the explorer's full arch set
    // charged from ONE compiled-trace walk (replay_many) vs the legacy
    // per-arch dyn `op_cost` replay of the same trace. Single-threaded
    // on purpose: this measures total replay *work*, not pool scaling.
    let probe = BenchJob::new(program, MemoryArchKind::banked(16));
    let trace = probe.capture_trace().unwrap();
    let archs: Vec<MemoryArchKind> = {
        let mut v = Vec::new();
        for p in space.points() {
            if !v.contains(&p.arch) {
                v.push(p.arch);
            }
        }
        v
    };
    let jobs: Vec<BenchJob> = archs.iter().map(|&a| BenchJob::new(program, a)).collect();
    let mut br = Bencher::new(2, 9);
    let dyn_s = br
        .bench(format!("replay_{}archs_dyn_op_cost", archs.len()), || {
            jobs.iter().map(|j| j.replay_trace(&trace).unwrap().report.total_cycles()).sum::<u64>()
        })
        .clone();
    println!("{}", dyn_s.line());
    let compile_s = br.bench("compile_trace", || CompiledTrace::compile(&trace).n_ops()).clone();
    println!("{}", compile_s.line());
    let compiled = CompiledTrace::compile(&trace);
    let batched_s = br
        .bench(format!("replay_{}archs_compiled_batched", archs.len()), || {
            replay_many(&compiled, &archs, u64::MAX)
                .into_iter()
                .map(|r| r.unwrap().total_cycles())
                .sum::<u64>()
        })
        .clone();
    println!("{}", batched_s.line());
    let batch_speedup = dyn_s.median().as_secs_f64() / batched_s.median().as_secs_f64();
    println!(
        "compiled batch replay speedup ({} archs, one walk vs {} walks): {batch_speedup:.2}x",
        archs.len(),
        archs.len()
    );
    // ISSUE 6: the same arch set through the lane-packed kernel.
    // `simd_speedup` (packed vs scalar replay_many) is machine-speed
    // independent, so CI gates it with an absolute floor.
    let packed_s = br
        .bench(format!("replay_{}archs_lane_packed", archs.len()), || {
            replay_many_packed(&compiled, &archs, u64::MAX)
                .into_iter()
                .map(|r| r.unwrap().total_cycles())
                .sum::<u64>()
        })
        .clone();
    println!("{}", packed_s.line());
    let simd_speedup = batched_s.median().as_secs_f64() / packed_s.median().as_secs_f64();
    println!(
        "lane-packed replay speedup over scalar replay_many ({} archs): {simd_speedup:.2}x",
        archs.len()
    );

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (ex_name, ex_res, ex_s) = &summaries[0];
    let (ha_name, ha_res, ha_s) = &summaries[1];
    debug_assert_eq!((*ex_name, *ha_name), ("exhaustive", "halving"));
    let json = format!(
        "{{\n  \"bench\": \"explore_{program}\",\n  \"unix_time\": {unix_time},\n  \
         \"points\": {n_points},\n  \"archs\": {archs},\n  \
         \"exhaustive_median_ms\": {ex_ms:.3},\n  \"exhaustive_points_per_sec\": {ex_pps:.1},\n  \
         \"halving_median_ms\": {ha_ms:.3},\n  \"halving_scored\": {ha_scored},\n  \
         \"halving_culled\": {ha_culled},\n  \"captures_per_explore\": 1,\n  \
         \"replay_dyn_archset_ms\": {dyn_ms:.3},\n  \
         \"compile_trace_ms\": {compile_ms:.3},\n  \
         \"replay_batched_archset_ms\": {batched_ms:.3},\n  \
         \"batch_speedup\": {batch_speedup:.3},\n  \
         \"replay_packed_archset_ms\": {packed_ms:.3},\n  \
         \"simd_speedup\": {simd_speedup:.3},\n  \
         \"system_explore_median_ms\": {sys_ms:.3},\n  \
         \"system_points\": {sys_points},\n  \
         \"system_replays\": {sys_replays}\n}}\n",
        archs = space.arch_count(),
        sys_ms = sys_s.median().as_secs_f64() * 1e3,
        sys_points = sys_result.points_scored,
        sys_replays = sys_result.replays,
        ex_ms = ex_s.median().as_secs_f64() * 1e3,
        ex_pps = ex_res.points_scored as f64 / ex_s.median().as_secs_f64(),
        ha_ms = ha_s.median().as_secs_f64() * 1e3,
        ha_scored = ha_res.points_scored,
        ha_culled = ha_res.points_culled,
        dyn_ms = dyn_s.median().as_secs_f64() * 1e3,
        compile_ms = compile_s.median().as_secs_f64() * 1e3,
        batched_ms = batched_s.median().as_secs_f64() * 1e3,
        packed_ms = packed_s.median().as_secs_f64() * 1e3,
    );
    match std::fs::write("BENCH_explore.json", &json) {
        Ok(()) => println!("wrote BENCH_explore.json"),
        Err(e) => eprintln!("could not write BENCH_explore.json: {e}"),
    }
    print!("{}", b.report());
}
