//! Bench: design-space explorer throughput on the smallest transpose
//! workload — emits `BENCH_explore.json` (points-evaluated/sec) so CI
//! can track the explorer's trajectory across PRs, next to
//! `BENCH_sweep.json`.

use soft_simt::benchkit::Bencher;
use soft_simt::coordinator::job::TraceCache;
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::explore::{explore, DesignSpace, Exhaustive, SearchStrategy, SuccessiveHalving};
use soft_simt::programs::library::program_by_name;

fn main() {
    let program = "transpose32"; // smallest registered transpose workload
    let dataset_kb = program_by_name(program).unwrap().dataset_kb();
    let space = DesignSpace::parametric(dataset_kb);
    let n_points = space.points().len();
    let runner = SweepRunner::default();
    println!(
        "explorer bench: {program}, {n_points} design points, {} architectures, {} workers",
        space.arch_count(),
        runner.workers()
    );

    let mut b = Bencher::new(1, 7);
    let mut summaries = Vec::new();
    let strategies: [(&str, &dyn SearchStrategy); 2] = [
        ("exhaustive", &Exhaustive),
        ("halving", &SuccessiveHalving { min_wave: 8 }),
    ];
    for (name, strategy) in strategies {
        // Cold cache each iteration: the measured unit is capture +
        // full search, the explorer's end-to-end cost.
        let result = {
            let cache = TraceCache::new();
            explore(program, &space, strategy, &runner, &cache).unwrap()
        };
        assert_eq!(result.captures, 1);
        let s = b
            .bench(format!("explore_{program}_{name}_cold"), || {
                let cache = TraceCache::new();
                explore(program, &space, strategy, &runner, &cache).unwrap().points_scored
            })
            .clone();
        let scored_per_sec = result.points_scored as f64 / s.median().as_secs_f64();
        println!(
            "{}  ({} scored, {} culled, {:.0} points-evaluated/s)",
            s.line(),
            result.points_scored,
            result.points_culled,
            scored_per_sec
        );
        summaries.push((name, result, s));
    }

    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (ex_name, ex_res, ex_s) = &summaries[0];
    let (ha_name, ha_res, ha_s) = &summaries[1];
    debug_assert_eq!((*ex_name, *ha_name), ("exhaustive", "halving"));
    let json = format!(
        "{{\n  \"bench\": \"explore_{program}\",\n  \"unix_time\": {unix_time},\n  \
         \"points\": {n_points},\n  \"archs\": {archs},\n  \
         \"exhaustive_median_ms\": {ex_ms:.3},\n  \"exhaustive_points_per_sec\": {ex_pps:.1},\n  \
         \"halving_median_ms\": {ha_ms:.3},\n  \"halving_scored\": {ha_scored},\n  \
         \"halving_culled\": {ha_culled},\n  \"captures_per_explore\": 1\n}}\n",
        archs = space.arch_count(),
        ex_ms = ex_s.median().as_secs_f64() * 1e3,
        ex_pps = ex_res.points_scored as f64 / ex_s.median().as_secs_f64(),
        ha_ms = ha_s.median().as_secs_f64() * 1e3,
        ha_scored = ha_res.points_scored,
        ha_culled = ha_res.points_culled,
    );
    match std::fs::write("BENCH_explore.json", &json) {
        Ok(()) => println!("wrote BENCH_explore.json"),
        Err(e) => eprintln!("could not write BENCH_explore.json: {e}"),
    }
    print!("{}", b.report());
}
