//! Observability-layer tests: histogram bucket math and percentiles,
//! registry counters and snapshots, span recording (and its
//! zero-cost-when-disabled contract), and the snapshot JSON shape.

use soft_simt::obs::{
    Counter, Hist, Histogram, MetricsRegistry, Phase, Span, SpanRecord, HIST_BUCKETS, PHASES,
    SPAN_RING_CAP,
};
use soft_simt::util::proptest::check;

// ---------------------------------------------------------------------
// Histogram buckets and percentiles.
// ---------------------------------------------------------------------

#[test]
fn percentiles_are_exact_on_known_inputs() {
    // 1 → [1,2), 2 → [2,4), 4 → [4,8), 8 → [8,16). Ranks: p50 hits the
    // 2nd observation (bucket [2,4), upper bound 3); p90 and p99 hit
    // the 4th (bucket [8,16), upper bound 15).
    let h = Histogram::new();
    for v in [1u64, 2, 4, 8] {
        h.record(v);
    }
    let counts = h.snapshot();
    assert_eq!(counts.total(), 4);
    assert_eq!(counts.percentile(0.50), 3);
    assert_eq!(counts.percentile(0.90), 15);
    assert_eq!(counts.percentile(0.99), 15);
}

#[test]
fn zero_has_its_own_bucket() {
    let h = Histogram::new();
    h.record(0);
    h.record(0);
    let counts = h.snapshot();
    assert_eq!(counts.counts[0], 2);
    assert_eq!(counts.percentile(0.50), 0);
    assert_eq!(counts.percentile(0.99), 0);
}

#[test]
fn huge_values_saturate_into_the_top_bucket() {
    let h = Histogram::new();
    h.record(u64::MAX);
    h.record(1u64 << 40);
    let counts = h.snapshot();
    assert_eq!(counts.counts[HIST_BUCKETS - 1], 2);
    // The saturating bucket reports its nominal upper bound.
    assert_eq!(counts.percentile(0.99), (1u64 << (HIST_BUCKETS - 1)) - 1);
}

#[test]
fn empty_histogram_reports_zero_percentiles() {
    let counts = Histogram::new().snapshot();
    assert_eq!(counts.total(), 0);
    assert_eq!(counts.percentile(0.50), 0);
    assert_eq!(counts.percentile(0.99), 0);
}

#[test]
fn bucket_placement_matches_the_powers_of_two() {
    // Each value lands in a bucket whose range [lo, hi] brackets it:
    // bucket 0 = {0}, bucket i = [2^(i-1), 2^i - 1].
    check("histogram bucket brackets its value", 500, |rng| {
        let v = rng.next_u64() >> (rng.next_u32() % 64);
        let h = Histogram::new();
        h.record(v);
        let counts = h.snapshot();
        let i = counts.counts.iter().position(|&c| c == 1).unwrap();
        let lo = if i == 0 { 0 } else { 1u64 << (i - 1) };
        assert!(v >= lo, "value {v} below bucket {i} lower bound {lo}");
        if i < HIST_BUCKETS - 1 {
            let hi = (1u64 << i) - 1;
            assert!(v <= hi, "value {v} above bucket {i} upper bound {hi}");
        }
    });
}

// ---------------------------------------------------------------------
// Registry counters and snapshots.
// ---------------------------------------------------------------------

#[test]
fn counters_start_zero_and_accumulate() {
    let m = MetricsRegistry::new();
    for c in Counter::ALL {
        assert_eq!(m.get(c), 0, "counter {} not zero at start", c.name());
    }
    m.inc(Counter::TraceCacheHits);
    m.add(Counter::TraceCacheHits, 4);
    m.add(Counter::TraceCacheMisses, 0); // no-op, not an underflow trap
    assert_eq!(m.get(Counter::TraceCacheHits), 5);
    assert_eq!(m.get(Counter::TraceCacheMisses), 0);
}

#[test]
fn snapshot_reports_every_counter_in_registry_order() {
    let m = MetricsRegistry::new();
    m.add(Counter::ReplayPackedLanesUsed, 51);
    m.observe(Hist::RequestMicros, 100);
    let snap = m.snapshot();
    assert_eq!(snap.counters.len(), Counter::ALL.len());
    for (i, c) in Counter::ALL.iter().enumerate() {
        assert_eq!(snap.counters[i].0, c.name());
    }
    assert_eq!(snap.counter("replay.packed_lanes_used"), Some(51));
    assert_eq!(snap.counter("requests.served"), Some(0));
    assert_eq!(snap.counter("no.such.counter"), None);
    let request_hist = &snap.histograms[Hist::RequestMicros as usize];
    assert_eq!(request_hist.name, "request_us");
    assert_eq!(request_hist.count, 1);
}

#[test]
fn counter_names_are_unique_and_dotted() {
    let names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
    let mut dedup = names.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), names.len(), "duplicate counter names: {names:?}");
    for n in names {
        assert!(n.contains('.'), "counter name '{n}' is not namespaced");
    }
}

#[test]
fn snapshot_json_has_the_documented_shape() {
    let m = MetricsRegistry::new();
    m.inc(Counter::RequestsServed);
    m.observe(Hist::ReplayMicros, 7);
    let mut span = m.span("run");
    span.time(Phase::Replay, || std::hint::black_box(17 * 3));
    m.finish_span(span);
    let json = m.snapshot().to_json();
    assert!(json.starts_with('{') && json.ends_with('}'));
    for needle in [
        "\"recording\":true",
        "\"counters\":{",
        "\"requests.served\":1",
        "\"histograms\":{",
        "\"replay_us\":{\"count\":1,",
        "\"spans\":[{\"op\":\"run\",\"wall_us\":",
        "\"phases_us\":{\"parse\":",
    ] {
        assert!(json.contains(needle), "missing `{needle}` in:\n{json}");
    }
}

// ---------------------------------------------------------------------
// Spans.
// ---------------------------------------------------------------------

#[test]
fn span_phase_sum_never_exceeds_wall_time() {
    // Phases are timed sub-intervals of the span's lifetime, so however
    // they interleave the attributed total must fit inside the wall
    // time. Randomize phase choice, work size and call count.
    check("span phase sum <= wall", 200, |rng| {
        let m = MetricsRegistry::new();
        let mut span = m.span("prop");
        let calls = 1 + rng.below(8);
        for _ in 0..calls {
            let phase = Phase::ALL[rng.below(PHASES as u32) as usize];
            let spin = rng.below(64);
            span.time(phase, || {
                let mut acc = 0u64;
                for i in 0..spin {
                    acc = acc.wrapping_add(std::hint::black_box(i as u64));
                }
                acc
            });
        }
        m.finish_span(span);
        let spans = m.spans();
        assert_eq!(spans.len(), 1);
        let rec = &spans[0];
        assert!(
            rec.phase_sum_nanos() <= rec.wall_nanos,
            "phase sum {} > wall {}",
            rec.phase_sum_nanos(),
            rec.wall_nanos
        );
    });
}

#[test]
fn disabled_recording_records_nothing() {
    let m = MetricsRegistry::new();
    m.set_recording(false);
    assert!(!m.recording());
    let mut span = m.span("run");
    assert!(!span.enabled());
    // The closure still runs — only the instrumentation is skipped.
    let out = span.time(Phase::Execute, || 42);
    assert_eq!(out, 42);
    span.add(Phase::Replay, std::time::Duration::from_millis(5));
    m.finish_span(span);
    assert!(m.spans().is_empty(), "disabled span must not reach the ring");
    assert!(!m.snapshot().recording);

    // And a standalone disabled span never yields a record at all.
    let span = Span::disabled("x");
    assert!(span.finish().is_none());

    // Counters keep working regardless of span recording.
    m.inc(Counter::RequestsServed);
    assert_eq!(m.get(Counter::RequestsServed), 1);
}

#[test]
fn span_ring_evicts_oldest_past_capacity() {
    let m = MetricsRegistry::new();
    for i in 0..(SPAN_RING_CAP + 5) {
        m.record_span(SpanRecord {
            op: "x",
            wall_nanos: i as u64,
            phase_nanos: [0; PHASES],
        });
    }
    let spans = m.spans();
    assert_eq!(spans.len(), SPAN_RING_CAP);
    assert_eq!(spans.first().unwrap().wall_nanos, 5, "oldest spans must be evicted");
    assert_eq!(spans.last().unwrap().wall_nanos, (SPAN_RING_CAP + 4) as u64);
}

#[test]
fn phase_names_cover_the_request_lifecycle_in_order() {
    let names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
    assert_eq!(names, ["parse", "cache_lookup", "execute", "compile", "replay", "render"]);
}
