//! Golden snapshots pinning the full 51-cell paper sweep tables and the
//! explorer Pareto frontier for the smallest transpose workload (ISSUE 4
//! satellite), rendered from the **batched** compiled-replay path.
//!
//! Snapshot protocol (insta-style bless-on-absence, dependency-free):
//!
//! - if `tests/data/golden_*.txt` exists, the freshly rendered output
//!   must match it **byte for byte** — any drift in cycle counts, table
//!   layout or frontier membership fails the test;
//! - if the file is missing (fresh checkout before the first blessed
//!   run), it is written and the test passes with a note;
//! - `GOLDEN_BLESS=1 cargo test --test golden_snapshot` deliberately
//!   re-blesses after an intentional change.
//!
//! The snapshots are backed by differential anchors that hold on every
//! run regardless of blessing state: the batched path must agree with
//! the coupled per-cell simulator on the same quantities
//! (`replay_parity.rs`, `replay_diff.rs`), so a blessed file can only
//! ever record coupled-simulator-equivalent numbers.

use soft_simt::coordinator::job::{BenchJob, TraceCache};
use soft_simt::coordinator::report;
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::explore::{explore, DesignSpace, Exhaustive};
use std::fmt::Write as _;
use std::path::Path;

/// Compare `actual` against the snapshot at `path` (relative to the
/// package root — resolved via `CARGO_MANIFEST_DIR`, so the test is
/// independent of the runner's working directory), blessing it when
/// absent or when `GOLDEN_BLESS` is set.
fn check_golden(path: &str, actual: &str) {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join(path);
    let bless = std::env::var_os("GOLDEN_BLESS").is_some();
    match std::fs::read_to_string(&p) {
        Ok(expected) if !bless => {
            assert_eq!(
                actual, expected,
                "snapshot {path} drifted — if the change is intentional, \
                 re-bless with GOLDEN_BLESS=1 cargo test --test golden_snapshot"
            );
        }
        _ => {
            if let Some(dir) = p.parent() {
                std::fs::create_dir_all(dir).expect("snapshot dir");
            }
            std::fs::write(&p, actual).expect("write snapshot");
            eprintln!("golden_snapshot: blessed {} ({} bytes)", p.display(), actual.len());
        }
    }
}

/// The full 51-cell paper sweep, rendered as Tables II and III plus the
/// per-cell CSV — all from the batched compiled-replay path.
#[test]
fn golden_51_cell_paper_sweep_tables() {
    let jobs = BenchJob::paper_sweep();
    assert_eq!(jobs.len(), 51);
    let cache = TraceCache::new();
    let results = SweepRunner::default()
        .run_with_cache(&jobs, &cache)
        .expect("paper sweep runs clean");
    assert_eq!(cache.compiled_len(), 6, "six workloads, six compiled traces");

    let mut out = String::new();
    out.push_str(&report::render_table2(&results));
    out.push('\n');
    out.push_str(&report::render_table3(&results));
    out.push('\n');
    out.push_str(&report::sweep_csv(&results));
    check_golden("tests/data/golden_paper_sweep.txt", &out);

    // Differential anchor, independent of blessing state: the batched
    // rendering equals the coupled per-cell rendering byte for byte.
    let coupled = SweepRunner::default().run(&jobs).expect("coupled sweep");
    assert_eq!(report::render_table2(&results), report::render_table2(&coupled));
    assert_eq!(report::render_table3(&results), report::render_table3(&coupled));
    assert_eq!(report::sweep_csv(&results), report::sweep_csv(&coupled));
}

/// The explorer's Pareto frontier for the smallest transpose workload on
/// the default parametric space, pinned point by point (label, capacity,
/// cycles, ALMs).
#[test]
fn golden_explorer_frontier_smallest_transpose() {
    let space = DesignSpace::parametric(8);
    let cache = TraceCache::new();
    let result = explore("transpose32", &space, &Exhaustive, &SweepRunner::default(), &cache)
        .expect("exploration runs clean");
    assert_eq!(result.captures, 1);

    let mut out = String::new();
    let _ = writeln!(
        out,
        "# explore transpose32 · parametric space · {} points · frontier {}",
        result.points_total,
        result.front.len()
    );
    for s in &result.front {
        let _ = writeln!(
            out,
            "{:24} {:>4} KB {:>10} cycles {:>8} ALMs",
            s.point.arch.label(),
            s.point.capacity_kb,
            s.cycles,
            s.footprint_alms.expect("frontier points are placeable"),
        );
    }
    check_golden("tests/data/golden_explore_transpose32.txt", &out);

    // Differential anchor: every frontier point's cycles equal a direct
    // coupled run on that architecture.
    for s in &result.front {
        let coupled = BenchJob::new("transpose32", s.point.arch).run().unwrap();
        assert_eq!(s.cycles, coupled.report.total_cycles(), "{}", s.point.label());
    }
}
