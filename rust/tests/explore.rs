//! Integration tests for the design-space explorer (ISSUE 2 acceptance):
//!
//! - a >50-point parametric space is served by exactly ONE functional
//!   execution per workload;
//! - every frontier point's replayed cycles equal a direct coupled
//!   `Machine::run_program` on that architecture;
//! - the pruning strategy's Pareto frontier equals the exhaustive
//!   frontier on small random spaces (property test);
//! - the lower-bound cost model is sound (lb <= exact) on random
//!   architectures — the invariant the pruning proof rests on.

use soft_simt::coordinator::job::{BenchJob, TraceCache};
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::explore::{
    explore, explore_system, DesignSpace, Evaluator, Exhaustive, ScoredPoint,
    SuccessiveHalving, SystemEvaluator, SystemPoint, SystemSpace,
};
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::mem::mapping::BankMapping;
use soft_simt::util::proptest::check;
use soft_simt::util::XorShift64;

#[test]
fn parametric_space_over_50_points_single_capture() {
    let space = DesignSpace::parametric(8);
    let points = space.points();
    assert!(points.len() > 50, "acceptance floor: got {} points", points.len());
    let cache = TraceCache::new();
    let runner = SweepRunner::new(4);
    let result = explore("transpose32", &space, &Exhaustive, &runner, &cache).unwrap();
    assert_eq!(result.points_total, points.len());
    assert_eq!(result.points_scored, points.len());
    assert_eq!(result.captures, 1, "one functional execution for the whole space");
    assert!(result.replays as usize <= space.arch_count());
    assert!(!result.front.is_empty());
    // The same guarantee holds for the pruning strategy on a warm cache:
    // zero further captures for arbitrarily many more points.
    let pruned = explore(
        "transpose32",
        &space,
        &SuccessiveHalving::default(),
        &runner,
        &cache,
    )
    .unwrap();
    assert_eq!(pruned.captures, 0, "warm cache: zero functional executions");
}

#[test]
fn frontier_cycles_match_direct_machine_run() {
    let space = DesignSpace::parametric(8);
    let cache = TraceCache::new();
    let runner = SweepRunner::new(4);
    let result = explore("transpose32", &space, &Exhaustive, &runner, &cache).unwrap();
    assert!(!result.front.is_empty());
    for s in &result.front {
        // BenchJob::run is the coupled path: functional execution +
        // timing replay in lockstep on the real architecture.
        let coupled = BenchJob::new("transpose32", s.point.arch).run().unwrap();
        assert_eq!(
            s.cycles,
            coupled.report.total_cycles(),
            "frontier point {} must match Machine::run_program",
            s.point.label()
        );
    }
}

fn front_key(front: &[ScoredPoint]) -> Vec<(String, u32, u64, u32)> {
    let mut v: Vec<(String, u32, u64, u32)> = front
        .iter()
        .map(|s| {
            (
                s.point.arch.label(),
                s.point.capacity_kb,
                s.cycles,
                s.footprint_alms.expect("frontier points are placeable"),
            )
        })
        .collect();
    v.sort();
    v
}

fn random_space(rng: &mut XorShift64) -> DesignSpace {
    let mut space = DesignSpace::new();
    // 1-3 bank counts x 1-3 mappings.
    let all_banks = [2u32, 4, 8, 16, 32];
    for _ in 0..1 + rng.below(3) {
        let banks = all_banks[rng.below(5) as usize];
        let mappings = [
            BankMapping::Lsb,
            BankMapping::Offset { shift: rng.below(4) },
            BankMapping::Xor,
        ];
        for _ in 0..1 + rng.below(3) {
            space = space.banked_grid([banks], [mappings[rng.below(3) as usize]]);
        }
    }
    if rng.chance(0.7) {
        space = space.multiport(1 << rng.below(4), 1, false);
    }
    if rng.chance(0.3) {
        space = space.multiport(4, 2, false);
    }
    // 1-2 capacities, sometimes over rooflines (those points simply
    // carry no footprint and stay off the frontier).
    let caps = [8u32, 16, 64, 128, 300];
    let mut s = space.capacities_kb([caps[rng.below(5) as usize]]);
    if rng.chance(0.5) {
        s = s.capacities_kb([caps[rng.below(5) as usize]]);
    }
    s
}

#[test]
fn pruning_front_equals_exhaustive_front_property() {
    // Shared cache: the workload is executed once for the whole property
    // run, every case is pure replay.
    let cache = TraceCache::new();
    let runner = SweepRunner::new(2);
    check("successive-halving frontier == exhaustive frontier", 15, |rng| {
        let space = random_space(rng);
        if space.points().is_empty() {
            return;
        }
        let min_wave = 1 + rng.below(3) as usize;
        let a = explore("transpose16", &space, &Exhaustive, &runner, &cache).unwrap();
        let b = explore(
            "transpose16",
            &space,
            &SuccessiveHalving { min_wave },
            &runner,
            &cache,
        )
        .unwrap();
        assert_eq!(
            front_key(&a.front),
            front_key(&b.front),
            "fronts diverged on a {}-point space (min_wave {min_wave})",
            space.points().len()
        );
        assert!(b.points_scored + b.points_culled == a.points_scored);
    });
}

#[test]
fn lower_bound_is_sound_property() {
    let cache = TraceCache::new();
    let eval = Evaluator::new("transpose16", &cache).unwrap();
    check("lower bound <= exact replay cycles", 40, |rng| {
        let arch = if rng.chance(0.5) {
            MemoryArchKind::Banked {
                banks: [2u32, 4, 8, 16, 32][rng.below(5) as usize],
                mapping: [
                    BankMapping::Lsb,
                    BankMapping::Offset { shift: rng.below(4) },
                    BankMapping::Xor,
                ][rng.below(3) as usize],
            }
        } else {
            MemoryArchKind::MultiPort {
                read_ports: 1 << rng.below(4),
                write_ports: 1 + rng.below(2),
                vb: false,
            }
        };
        let lb = eval.lower_bound_cycles(arch);
        let exact = eval.replay_arch(arch).unwrap();
        assert!(lb <= exact, "{arch}: lower bound {lb} > exact {exact}");
    });
}

/// ISSUE 10 acceptance, at the public API: a single-processor,
/// 16-lane system point is **bit-identical** to the flat explorer's
/// replay for every paper-nine memory — the system contention model is
/// a strict extension, never a perturbation.
#[test]
fn system_p1_replay_is_bit_identical_to_flat_replay() {
    let cache = TraceCache::new();
    for program in ["transpose32", "fft4096r8"] {
        let sys = SystemEvaluator::new(program, &cache).unwrap();
        for arch in MemoryArchKind::table3_nine() {
            let flat = sys.flat().replay_arch(arch).unwrap();
            let one = sys.replay(SystemPoint::single(arch, 8)).unwrap();
            assert_eq!(one, flat, "{program} on {arch}: P=1 diverged from flat replay");
        }
    }
    assert_eq!(cache.len(), 2, "one trace per workload for all eighteen comparisons");
}

/// The system parametric space — {1,2,4} cores × {16,32,64} lanes ×
/// paper nine × 3 capacities — scores from ONE functional execution,
/// and cycles are monotone non-decreasing in the core count.
#[test]
fn system_parametric_space_single_capture_and_monotone() {
    let cache = TraceCache::new();
    let space = SystemSpace::parametric(8);
    let r = explore_system("transpose32", &space, &cache).unwrap();
    assert_eq!(r.captures, 1, "one functional execution for the whole system space");
    assert_eq!(r.points_total, 3 * 3 * 9 * 3);
    assert_eq!(r.points_scored, r.points_total);
    assert!(!r.front.is_empty());
    // Monotonicity across the scored set: same lanes/memory/capacity,
    // more processors never means fewer cycles.
    for a in &r.scored {
        for b in &r.scored {
            if a.point.lanes == b.point.lanes
                && a.point.mem == b.point.mem
                && a.point.capacity_kb == b.point.capacity_kb
                && a.point.processors < b.point.processors
            {
                assert!(
                    a.cycles <= b.cycles,
                    "{} has more cycles than {}",
                    a.point.label(),
                    b.point.label()
                );
            }
        }
    }
}

#[test]
fn explorer_covers_reduction_workload() {
    // The satellite workload runs through the same single-capture path.
    let space = DesignSpace::parametric(64);
    let cache = TraceCache::new();
    let runner = SweepRunner::new(4);
    let r = explore(
        "reduction4096",
        &space,
        &SuccessiveHalving::default(),
        &runner,
        &cache,
    )
    .unwrap();
    assert_eq!(r.captures, 1);
    assert_eq!(r.dataset_kb, 64);
    assert!(!r.front.is_empty());
    // On a stride-4 workload some offset-mapped memory must beat the
    // plain LSB map of the same bank count wherever both were scored.
    let cycles_of = |arch: MemoryArchKind| {
        r.scored.iter().find(|s| s.point.arch == arch).map(|s| s.cycles)
    };
    if let (Some(lsb), Some(off)) = (
        cycles_of(MemoryArchKind::banked(16)),
        cycles_of(MemoryArchKind::banked_offset(16)),
    ) {
        assert!(off < lsb, "offset {off} !< lsb {lsb} on strided reduction");
    }
}
