//! Service-layer integration suite: wire round-trips for every request
//! variant, the serve loop over in-memory pipes, the batch trace-sharing
//! economy (asserted on the metrics registry through `Request::Stats`),
//! and CLI-vs-engine output parity for `run`, `sweep` and `explore`.

use soft_simt::coordinator::job::{BenchJob, TraceCache};
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::explore::{explore, DesignSpace, Exhaustive};
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::service::wire::{self, parse_json, Json};
use soft_simt::service::{
    ExploreObjective, ExploreSpec, ExploreStrategy, Request, Response, ServiceError, SimtEngine,
    StatsScope, TableKind,
};
use soft_simt::sim::stats::RunReport;

const ASM_SRC: &str = ".threads 16\n    tid r0\n    st [r0], r0\n    halt\n";

/// One request of every variant (cheap parameters; used by the
/// round-trip and serve-batch tests).
fn every_variant() -> Vec<Request> {
    vec![
        Request::Run {
            program: "transpose32".into(),
            mem: MemoryArchKind::banked_offset(16),
        },
        Request::Sweep { all: false },
        Request::Table(TableKind::Table1),
        Request::Advise { program: "transpose32".into() },
        Request::Explore {
            program: "transpose32".into(),
            strategy: ExploreStrategy::Halving,
            spec: None,
        },
        Request::Validate { artifacts_dir: Some("artifacts".into()) },
        Request::Asm { source: ASM_SRC.into(), mem: MemoryArchKind::banked(4) },
        Request::Disasm { program: "transpose32".into() },
        Request::List,
        Request::Stats { scope: StatsScope::Engine },
    ]
}

#[test]
fn wire_roundtrip_every_request_variant() {
    let mut variants = every_variant();
    // Parametric memories and non-default fields must survive too.
    variants.push(Request::Run {
        program: "fft4096r8".into(),
        mem: MemoryArchKind::parse("banked8-offset3").unwrap(),
    });
    variants.push(Request::Run {
        program: "reduction4096".into(),
        mem: MemoryArchKind::parse("2r-1w").unwrap(),
    });
    variants.push(Request::Sweep { all: true });
    variants.push(Request::Table(TableKind::Fig9));
    variants.push(Request::Explore {
        program: "fft4096r16".into(),
        strategy: ExploreStrategy::Exhaustive,
        spec: None,
    });
    // Spec-bearing explores: a full system spec and a partial flat one.
    variants.push(Request::Explore {
        program: "transpose32".into(),
        strategy: ExploreStrategy::Exhaustive,
        spec: Some(ExploreSpec {
            banks: Some(vec![4, 16]),
            mappings: Some(vec!["offset2".into()]),
            multiport: Some(vec!["4r-1w".into()]),
            capacities_kb: Some(vec![8, 32]),
            processors: Some(vec![1, 2, 4]),
            lanes: Some(vec![16, 32]),
            objective: Some(ExploreObjective::ThroughputPerAlm),
            target_clock_mhz: Some(700.0),
        }),
    });
    variants.push(Request::Explore {
        program: "fft4096r8".into(),
        strategy: ExploreStrategy::Halving,
        spec: Some(ExploreSpec {
            banks: Some(vec![8]),
            ..Default::default()
        }),
    });
    variants.push(Request::Validate { artifacts_dir: None });
    variants.push(Request::Stats { scope: StatsScope::Session });
    for req in &variants {
        let line = wire::request_to_json(req);
        let parsed = wire::requests_from_line(&line)
            .unwrap_or_else(|e| panic!("'{line}' must parse: {e}"));
        assert_eq!(parsed.as_slice(), std::slice::from_ref(req), "round-trip of {line}");
        // And as a member of a batch array line.
        let batch_line = format!("[{line},{}]", wire::request_to_json(&Request::List));
        let batch = wire::requests_from_line(&batch_line).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(&batch[0], req);
    }
}

#[test]
fn serve_loop_over_in_memory_pipes() {
    let engine = SimtEngine::with_runner(SweepRunner::new(2));
    let input = "\
{\"op\":\"list\"}\n\
\n\
{\"op\":\"run\",\"program\":\"transpose32\",\"mem\":\"16-banks\"}\n\
this is not json\n\
{\"op\":\"frobnicate\"}\n\
[{\"op\":\"disasm\",\"program\":\"transpose32\"},{\"op\":\"run\",\"program\":\"nope\"}]\n";
    let mut output = Vec::new();
    wire::serve(&engine, input.as_bytes(), &mut output).unwrap();
    let text = String::from_utf8(output).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5, "one response line per non-blank request line:\n{text}");
    // Every line is valid JSON.
    for line in &lines {
        parse_json(line).unwrap_or_else(|e| panic!("invalid response line '{line}': {e}"));
    }
    assert!(lines[0].contains("\"ok\":true") && lines[0].contains("\"op\":\"list\""));
    assert!(lines[1].contains("\"op\":\"run\"") && lines[1].contains("\"total_cycles\":"));
    assert!(lines[2].contains("\"ok\":false"), "bad JSON answered in-band: {}", lines[2]);
    assert!(lines[3].contains("unknown op"), "{}", lines[3]);
    // The batch line: array of two results, second is a typed error.
    let Json::Arr(items) = parse_json(lines[4]).unwrap() else {
        panic!("batch answered with an array: {}", lines[4])
    };
    assert_eq!(items.len(), 2);
    assert_eq!(items[0].get("ok"), Some(&Json::Bool(true)));
    assert_eq!(items[1].get("ok"), Some(&Json::Bool(false)));
    assert_eq!(items[1].get("exit_code").and_then(Json::as_f64), Some(2.0));
}

/// The acceptance batch: paper sweep + explore + ten repeat runs costs
/// exactly six functional executions (one per distinct workload), and
/// repeating the whole batch adds zero. The count is asserted the way a
/// client would see it: on the `Stats` response closing the batch.
#[test]
fn batch_shares_traces_across_sweep_explore_and_runs() {
    let engine = SimtEngine::with_runner(SweepRunner::new(4));
    let mut batch = vec![
        Request::Sweep { all: false },
        Request::Explore {
            program: "transpose32".into(),
            strategy: ExploreStrategy::Halving,
            spec: None,
        },
    ];
    for i in 0..10 {
        let archs = MemoryArchKind::table3_nine();
        batch.push(Request::Run {
            program: if i % 2 == 0 { "transpose32".into() } else { "fft4096r8".into() },
            mem: archs[i % archs.len()],
        });
    }
    batch.push(Request::Stats { scope: StatsScope::Engine });
    let responses = engine.handle_batch(&batch);
    assert_eq!(responses.len(), batch.len());
    for (req, resp) in batch.iter().zip(&responses) {
        assert!(resp.is_ok(), "{req:?} failed: {:?}", resp.as_ref().err());
    }
    // Six distinct (program, seed) workloads in the paper sweep; the
    // explore and all ten runs ride on those traces. The closing Stats
    // request snapshots the registry after everything before it.
    let Ok(Response::Stats(snap)) = responses.last().unwrap() else {
        panic!("batch ends with the stats snapshot")
    };
    assert_eq!(snap.counter("exec.functional_executions"), Some(6));
    // Batch items run concurrently, so several requests may each count
    // a cold miss on the same key before its single-flight capture
    // lands — at least one per distinct workload, possibly more.
    assert!(
        snap.counter("trace_cache.misses").unwrap() >= 6,
        "every distinct workload missed at least once: {:?}",
        snap.counters
    );
    assert_eq!(engine.cache().len(), 6);

    // Repeat requests leave the cache untouched — and the warm pass
    // advances the hit counter without a single new execution.
    let before = engine.cache().len();
    let responses = engine.handle_batch(&batch);
    responses.iter().for_each(|r| assert!(r.is_ok()));
    assert_eq!(engine.cache().len(), before, "repeat batch captures nothing");
    let Ok(Response::Stats(snap)) = responses.last().unwrap() else {
        panic!("batch ends with the stats snapshot")
    };
    assert_eq!(snap.counter("exec.functional_executions"), Some(6));
    assert!(
        snap.counter("trace_cache.hits").unwrap() >= 1,
        "warm batch must be served from the trace cache: {:?}",
        snap.counters
    );
    assert!(snap.counter("replay.packed_invocations").unwrap() >= 2, "both sweeps packed");
}

/// Pre-redesign `print_report`, verbatim — the pinned `run` stdout.
fn legacy_print_report(r: &RunReport) -> String {
    use std::fmt::Write;
    let s = &r.stats;
    let mut out = String::new();
    writeln!(out, "program      {}", r.program).unwrap();
    writeln!(out, "memory       {}", r.arch).unwrap();
    writeln!(out, "threads      {}", r.threads).unwrap();
    writeln!(
        out,
        "INT / Imm / FP / Other cycles: {} / {} / {} / {}",
        s.int_cycles, s.imm_cycles, s.fp_cycles, s.other_cycles
    )
    .unwrap();
    writeln!(out, "D load   {} cycles over {} ops", s.d_load_cycles, s.d_load_ops).unwrap();
    if s.tw_load_ops > 0 {
        writeln!(out, "TW load  {} cycles over {} ops", s.tw_load_cycles, s.tw_load_ops)
            .unwrap();
    }
    writeln!(out, "store    {} cycles over {} ops", s.store_cycles, s.store_ops).unwrap();
    writeln!(out, "stalls   write-buffer {} / drain {}", s.wbuf_stall_cycles, s.drain_cycles)
        .unwrap();
    writeln!(
        out,
        "total    {} cycles  ({:.2} us @ {:.0} MHz)",
        r.total_cycles(),
        r.time_us(),
        r.arch.fmax_mhz()
    )
    .unwrap();
    if let Some(e) = r.r_bank_eff() {
        writeln!(out, "R bank eff.  {:.1}%", e * 100.0).unwrap();
    }
    if let Some(e) = r.tw_bank_eff() {
        writeln!(out, "TW bank eff. {:.1}%", e * 100.0).unwrap();
    }
    if let Some(e) = r.w_bank_eff() {
        writeln!(out, "W bank eff.  {:.1}%", e * 100.0).unwrap();
    }
    writeln!(out, "compute eff. {:.1}%", r.compute_efficiency() * 100.0).unwrap();
    out
}

#[test]
fn cli_run_output_is_byte_identical_to_pre_redesign() {
    // The old CLI: BenchJob::new(p, m).run() then print_report.
    for (program, mem) in [
        ("transpose32", MemoryArchKind::banked_offset(16)),
        ("fft4096r8", MemoryArchKind::mp_4r1w()),
        ("reduction4096", MemoryArchKind::banked(4)),
    ] {
        let legacy = legacy_print_report(
            &BenchJob::new(program, mem).run().unwrap().report,
        );
        let engine = SimtEngine::with_runner(SweepRunner::new(2));
        let resp = engine
            .handle(&Request::Run { program: program.into(), mem })
            .unwrap();
        assert_eq!(resp.render(), legacy, "{program} on {mem}");
    }
}

#[test]
fn cli_sweep_output_is_byte_identical_to_pre_redesign() {
    use soft_simt::coordinator::report;
    // The old CLI: SweepRunner::default().run_cached(paper_sweep), then
    // table2 + table3 + fig9 (and the CSV for --csv).
    let jobs = BenchJob::paper_sweep();
    let runner = SweepRunner::new(4);
    let results = runner.run_cached(&jobs).unwrap();
    let mut legacy = String::new();
    legacy.push_str(&report::render_table2(&results));
    legacy.push_str(&report::render_table3(&results));
    legacy.push_str(&report::render_fig9(&results));
    let legacy_csv = report::sweep_csv(&results);

    let engine = SimtEngine::with_runner(SweepRunner::new(4));
    let resp = engine.handle(&Request::Sweep { all: false }).unwrap();
    assert_eq!(resp.render(), legacy);
    let Response::Sweep(sweep) = &resp else { panic!("sweep response") };
    assert_eq!(sweep.csv(), legacy_csv);
}

#[test]
fn cli_explore_output_is_byte_identical_to_pre_redesign() {
    // The old CLI: a private cache + runner, explore(), render().
    let program = "transpose32";
    let workload = soft_simt::programs::library::program_by_name(program).unwrap();
    let space = DesignSpace::parametric(workload.dataset_kb());
    let runner = SweepRunner::new(4);
    let cache = TraceCache::new();
    let legacy = explore(program, &space, &Exhaustive, &runner, &cache).unwrap().render();

    let engine = SimtEngine::with_runner(SweepRunner::new(4));
    let resp = engine
        .handle(&Request::Explore {
            program: program.into(),
            strategy: ExploreStrategy::Exhaustive,
            spec: None,
        })
        .unwrap();
    assert_eq!(resp.render(), legacy);
}

/// The redesign's byte-identity guarantee, end to end over the serve
/// transport: a pre-redesign explore wire line (no `spec` field) must
/// produce the exact response line it always did — i.e. the same bytes
/// a from-source legacy pipeline renders.
#[test]
fn specless_explore_wire_line_answers_byte_identically() {
    let program = "transpose32";
    let workload = soft_simt::programs::library::program_by_name(program).unwrap();
    let space = DesignSpace::parametric(workload.dataset_kb());
    let legacy_result =
        explore(program, &space, &Exhaustive, &SweepRunner::new(2), &TraceCache::new()).unwrap();
    let legacy_line = format!(
        "{{\"ok\":true,\"op\":\"explore\",\"result\":{},\"text\":{}}}",
        legacy_result.to_json().replace('\n', " "),
        soft_simt::util::fmt::json_str(&legacy_result.render()),
    );

    let engine = SimtEngine::with_runner(SweepRunner::new(2));
    let input = "{\"op\":\"explore\",\"program\":\"transpose32\",\"strategy\":\"exhaustive\"}\n";
    let mut output = Vec::new();
    wire::serve(&engine, input.as_bytes(), &mut output).unwrap();
    let text = String::from_utf8(output).unwrap();
    assert_eq!(text.trim_end(), legacy_line);
}

/// A system-shaped spec over the wire: the engine answers with the
/// system explorer's document under the same `explore` op, from one
/// functional execution.
#[test]
fn system_spec_explore_over_the_wire() {
    let engine = SimtEngine::with_runner(SweepRunner::new(2));
    let input = "{\"op\":\"explore\",\"program\":\"transpose32\",\"strategy\":\"exhaustive\",\
                 \"spec\":{\"banks\":[16],\"mappings\":[\"offset\"],\"multiport\":[],\
                 \"capacities_kb\":[8],\"processors\":[1,2,4],\"lanes\":[16,32,64]}}\n";
    let mut output = Vec::new();
    wire::serve(&engine, input.as_bytes(), &mut output).unwrap();
    let text = String::from_utf8(output).unwrap();
    let v = parse_json(text.trim_end()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(v.get("op").and_then(Json::as_str), Some("explore"));
    let result = v.get("result").expect("system result document");
    assert_eq!(result.get("points_total").and_then(Json::as_f64), Some(9.0));
    assert_eq!(result.get("captures").and_then(Json::as_f64), Some(1.0));
    assert!(result.get("front").is_some() && result.get("scorecard").is_some());
    assert_eq!(engine.functional_executions(), 1);
}

/// The acceptance batch over the actual stdin/stdout transport: one
/// array line containing every request variant, answered in order.
#[test]
fn serve_answers_a_batch_of_every_variant() {
    let engine = SimtEngine::with_runner(SweepRunner::new(4));
    let parts: Vec<String> = every_variant().iter().map(wire::request_to_json).collect();
    let input = format!("[{}]\n", parts.join(","));
    let mut output = Vec::new();
    wire::serve(&engine, input.as_bytes(), &mut output).unwrap();
    let text = String::from_utf8(output).unwrap();
    assert_eq!(text.lines().count(), 1, "one batch line → one response line");
    let Json::Arr(items) = parse_json(text.trim_end()).unwrap() else {
        panic!("batch response is an array")
    };
    assert_eq!(items.len(), every_variant().len());
    let expected_ops = [
        "run", "sweep", "table", "advise", "explore", "validate", "asm", "disasm", "list",
        "stats",
    ];
    for (item, expected) in items.iter().zip(expected_ops) {
        assert_eq!(
            item.get("ok"),
            Some(&Json::Bool(true)),
            "{expected} failed: {item:?}"
        );
        assert_eq!(item.get("op").and_then(Json::as_str), Some(expected));
        assert!(item.get("text").is_some(), "{expected} carries its rendering");
    }
    // Validation (host references, no artifacts in the test checkout)
    // must pass wholesale.
    let validate = &items[5];
    assert_eq!(validate.get("failed").and_then(Json::as_f64), Some(0.0));
    // The whole batch shared the engine cache: 6 sweep workloads + 1
    // asm run (validation's functional checks are uncounted by design).
    assert_eq!(engine.functional_executions(), 7);
    assert_eq!(engine.cache().len(), 6);
    // The closing stats item saw every earlier request of the batch:
    // its snapshot is taken before its own bookkeeping lands.
    let stats = items.last().unwrap();
    let counters = stats.get("counters").expect("stats carries counters");
    assert_eq!(
        counters.get("exec.functional_executions").and_then(Json::as_f64),
        Some(7.0)
    );
    assert_eq!(
        counters.get("requests.served").and_then(Json::as_f64),
        Some((expected_ops.len() - 1) as f64)
    );
    assert!(stats.get("histograms").is_some() && stats.get("spans").is_some());
}

/// A serve session's telemetry, end to end over the wire: a repeated
/// `run` is served warm from the trace cache, and the closing `stats`
/// line reports it — the ISSUE's acceptance check, over real pipes.
#[test]
fn serve_stats_line_reports_warm_cache_and_spans() {
    let engine = SimtEngine::with_runner(SweepRunner::new(2));
    let input = "\
{\"op\":\"run\",\"program\":\"transpose32\",\"mem\":\"16-banks\"}\n\
{\"op\":\"run\",\"program\":\"transpose32\",\"mem\":\"16-banks\"}\n\
[{\"op\":\"list\"},{\"op\":\"stats\"}]\n\
{\"op\":\"stats\"}\n";
    let mut output = Vec::new();
    wire::serve(&engine, input.as_bytes(), &mut output).unwrap();
    let text = String::from_utf8(output).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 4);
    // Stats inside a batch array answers like any other member.
    let Json::Arr(items) = parse_json(lines[2]).unwrap() else {
        panic!("batch line answers an array: {}", lines[2])
    };
    assert_eq!(items[1].get("op").and_then(Json::as_str), Some("stats"));
    assert_eq!(items[1].get("ok"), Some(&Json::Bool(true)));
    // The closing standalone stats line: the second run was warm (one
    // execution, at least one hit), and the earlier wire lines already
    // landed spans in the ring.
    let stats = parse_json(lines[3]).unwrap();
    let counters = stats.get("counters").expect("counters object");
    assert_eq!(counters.get("exec.functional_executions").and_then(Json::as_f64), Some(1.0));
    assert_eq!(counters.get("trace_cache.misses").and_then(Json::as_f64), Some(1.0));
    assert!(counters.get("trace_cache.hits").and_then(Json::as_f64).unwrap() >= 1.0);
    assert!(counters.get("replay.scalar_invocations").and_then(Json::as_f64).unwrap() >= 2.0);
    // Four requests answered before this snapshot (2 runs, list, the
    // batch's stats); the snapshot precedes its own bookkeeping.
    assert_eq!(counters.get("requests.served").and_then(Json::as_f64), Some(4.0));
    let Some(Json::Arr(spans)) = stats.get("spans").cloned() else {
        panic!("stats carries a spans array")
    };
    // Two single-object lines, then the batch line: its two items each
    // record their own request span before the enclosing "batch" line
    // span lands.
    assert_eq!(spans.len(), 5, "run, run, list, stats, batch");
    assert_eq!(spans[2].get("op").and_then(Json::as_str), Some("list"));
    assert_eq!(spans[3].get("op").and_then(Json::as_str), Some("stats"));
    assert_eq!(spans[4].get("op").and_then(Json::as_str), Some("batch"));
}

#[test]
fn engine_errors_map_to_unified_exit_codes() {
    let engine = SimtEngine::with_runner(SweepRunner::new(1));
    let e = engine
        .handle(&Request::Disasm { program: "quicksort".into() })
        .unwrap_err();
    assert!(matches!(e, ServiceError::UnknownProgram(_)));
    assert_eq!(e.exit_code(), 2);
    let e = wire::requests_from_line("{\"op\":\"run\",\"program\":\"t\",\"mem\":\"17-banks\"}")
        .unwrap_err();
    assert!(matches!(e, ServiceError::UnknownMemory(_)));
    assert!(e.to_string().contains(soft_simt::mem::arch::PARSE_GRAMMAR));
    let e = engine
        .handle(&Request::Asm { source: "halt\n".into(), mem: MemoryArchKind::banked(4) })
        .unwrap_err();
    assert_eq!(e.exit_code(), 1, "assembly failures are execution-class");
}
