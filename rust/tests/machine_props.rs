//! Integration: cross-cutting machine properties — random programs,
//! failure injection and architecture-equivalence invariants that unit
//! tests cannot see from inside one module.

use soft_simt::isa::asm::{assemble, disassemble};
use soft_simt::isa::inst::Instruction;
use soft_simt::isa::opcode::Opcode;
use soft_simt::isa::program::Program;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::sim::config::MachineConfig;
use soft_simt::sim::machine::{Machine, SimError};
use soft_simt::util::proptest::check;
use soft_simt::util::XorShift64;

const MEM_WORDS: usize = 4096;

/// Generate a random *memory-safe, divergence-free* program: addresses are
/// masked into range, branches are never emitted.
fn random_straightline(rng: &mut XorShift64, max_len: usize) -> Program {
    let n = 2 + rng.below(max_len as u32) as usize;
    let mut insts = vec![Instruction::i(Opcode::Tid, 0, 0, 0)];
    for _ in 0..n {
        let r = |rng: &mut XorShift64| 1 + rng.below(30) as u8;
        let inst = match rng.below(10) {
            0 => Instruction::i(Opcode::Ldi, r(rng), 0, rng.next_u32() as u16),
            1 => Instruction::r(Opcode::Iadd, r(rng), r(rng), r(rng)),
            2 => Instruction::i(Opcode::Ishri, r(rng), r(rng), rng.below(8) as u16),
            3 => Instruction::r(Opcode::Fadd, r(rng), r(rng), r(rng)),
            4 => Instruction::r(Opcode::Fmul, r(rng), r(rng), r(rng)),
            5 | 6 => {
                // Mask an address register into range, then load.
                let a = r(rng);
                insts.push(Instruction::i(Opcode::Iandi, a, a, (MEM_WORDS - 1) as u16));
                Instruction::i(Opcode::Ld, r(rng), a, 0)
            }
            7 | 8 => {
                let a = r(rng);
                insts.push(Instruction::i(Opcode::Iandi, a, a, (MEM_WORDS - 1) as u16));
                let op = if rng.chance(0.5) { Opcode::St } else { Opcode::Stnb };
                Instruction::r(op, 0, a, r(rng))
            }
            _ => Instruction::i(Opcode::Iaddi, r(rng), r(rng), rng.next_u32() as u16),
        };
        insts.push(inst);
    }
    insts.push(Instruction::z(Opcode::Halt));
    Program::new("fuzz", 16 * (1 + rng.below(8)), insts)
}

#[test]
fn all_archs_functionally_identical_on_random_programs() {
    // Timing differs; memory images and (observable) results must not.
    check("9 archs agree on random programs", 40, |rng| {
        let program = random_straightline(rng, 40);
        let seed = rng.next_u64();
        let mut images: Vec<Vec<u32>> = Vec::new();
        for arch in MemoryArchKind::table3_nine() {
            let mut m =
                Machine::new(MachineConfig::for_arch(arch).with_mem_words(MEM_WORDS));
            let mut img_rng = XorShift64::new(seed);
            let init: Vec<u32> = (0..MEM_WORDS as u32).map(|_| img_rng.next_u32()).collect();
            m.load_image(0, &init);
            m.run_program(&program).expect("fuzz program runs");
            images.push(m.mem().image());
        }
        for img in &images[1..] {
            assert_eq!(img, &images[0], "program:\n{}", disassemble(&program));
        }
    });
}

#[test]
fn fast_and_exact_timing_agree_on_random_programs() {
    check("fast == exact banked timing", 40, |rng| {
        let program = random_straightline(rng, 40);
        for banks in [4u32, 8, 16] {
            let arch = if rng.chance(0.5) {
                MemoryArchKind::banked(banks)
            } else {
                MemoryArchKind::banked_offset(banks)
            };
            let mut exact =
                Machine::new(MachineConfig::for_arch(arch).with_mem_words(MEM_WORDS));
            let mut fast = Machine::new(
                MachineConfig::for_arch(arch)
                    .with_mem_words(MEM_WORDS)
                    .with_fast_timing(),
            );
            let re = exact.run_program(&program).unwrap();
            let rf = fast.run_program(&program).unwrap();
            assert_eq!(re.total_cycles(), rf.total_cycles());
            assert_eq!(re.stats, rf.stats);
        }
    });
}

#[test]
fn elapsed_never_exceeds_attributed_for_blocking_programs() {
    // With only blocking stores, elapsed == attributed total; with
    // non-blocking stores elapsed ≤ attributed (overlap only helps).
    check("elapsed vs attributed bound", 60, |rng| {
        let program = random_straightline(rng, 30);
        let mut m = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked(8)).with_mem_words(MEM_WORDS),
        );
        let r = m.run_program(&program).unwrap();
        assert!(
            r.total_cycles() <= r.stats.attributed_total() + r.stats.drain_cycles,
            "elapsed {} attributed {} drain {}",
            r.total_cycles(),
            r.stats.attributed_total(),
            r.stats.drain_cycles,
        );
    });
}

#[test]
fn asm_binary_text_roundtrip_via_simulation() {
    // asm text → Program → binary → Program → identical simulation.
    check("binary roundtrip preserves behaviour", 25, |rng| {
        let program = random_straightline(rng, 25);
        let text = disassemble(&program);
        let reparsed = assemble(&text).expect("roundtrip");
        let binary = Program::decode("bin", program.threads, &program.encode()).unwrap();
        let arch = MemoryArchKind::banked_offset(16);
        let mut runs = Vec::new();
        for p in [&program, &reparsed, &binary] {
            let mut m =
                Machine::new(MachineConfig::for_arch(arch).with_mem_words(MEM_WORDS));
            runs.push(m.run_program(p).unwrap().total_cycles());
        }
        assert_eq!(runs[0], runs[1]);
        assert_eq!(runs[0], runs[2]);
    });
}

// ---------------------------------------------------------------- failure injection

#[test]
fn reports_oob_thread_and_address() {
    let src = "
.threads 32
    tid   r0
    imuli r1, r0, 1000
    ld    r2, [r1]
    halt
";
    let p = assemble(src).unwrap();
    let mut m =
        Machine::new(MachineConfig::for_arch(MemoryArchKind::banked(16)).with_mem_words(4096));
    match m.run_program(&p) {
        Err(SimError::InvalidAddress { thread, addr, pc, .. }) => {
            assert_eq!(pc, 2);
            assert_eq!(addr, thread * 1000);
            assert!(addr >= 4096);
        }
        other => panic!("expected InvalidAddress, got {other:?}"),
    }
}

#[test]
fn store_address_also_bounds_checked() {
    let src = "
.threads 16
    ldi  r0, 0
    lui  r0, 2
    st   [r0], r0
    halt
";
    let p = assemble(src).unwrap();
    let mut m =
        Machine::new(MachineConfig::for_arch(MemoryArchKind::mp_4r1w()).with_mem_words(4096));
    assert!(matches!(m.run_program(&p), Err(SimError::InvalidAddress { .. })));
}

#[test]
fn jump_target_validated_at_execution() {
    let p = Program::new(
        "badjmp",
        16,
        vec![Instruction::i(Opcode::Jmp, 0, 0, 999), Instruction::z(Opcode::Halt)],
    );
    let mut m = Machine::new(MachineConfig::for_arch(MemoryArchKind::banked(4)));
    assert!(matches!(m.run_program(&p), Err(SimError::BadJumpTarget { pc: 0, target: 999 })));
}

#[test]
fn machine_reusable_after_error() {
    // A faulting program must not poison the machine for the next run.
    let bad = Program::new(
        "bad",
        16,
        vec![Instruction::i(Opcode::Jmp, 0, 0, 999), Instruction::z(Opcode::Halt)],
    );
    let good = assemble(".threads 16\ntid r0\nst [r0], r0\nhalt\n").unwrap();
    let mut m = Machine::new(MachineConfig::for_arch(MemoryArchKind::banked(8)));
    assert!(m.run_program(&bad).is_err());
    let r = m.run_program(&good).expect("machine still usable");
    assert!(r.total_cycles() > 0);
    assert_eq!(m.mem().peek(5), 5);
}
