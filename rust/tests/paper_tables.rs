//! Integration: the full 51-cell paper sweep, with the qualitative claims
//! of §V/§VI asserted against the sweep results — the executable form of
//! EXPERIMENTS.md.

use soft_simt::area::fig9::perf_per_area;
use soft_simt::coordinator::job::{BenchJob, BenchResult};
use soft_simt::coordinator::report;
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::mem::arch::MemoryArchKind;
use std::sync::OnceLock;

fn sweep() -> &'static Vec<BenchResult> {
    static SWEEP: OnceLock<Vec<BenchResult>> = OnceLock::new();
    SWEEP.get_or_init(|| {
        SweepRunner::default()
            .run(&BenchJob::paper_sweep())
            .expect("paper sweep runs clean")
    })
}

fn get<'a>(results: &'a [BenchResult], program: &str, arch: MemoryArchKind) -> &'a BenchResult {
    results
        .iter()
        .find(|r| r.job.program == program && r.job.arch == arch)
        .unwrap()
}

#[test]
fn sweep_covers_51_cells() {
    assert_eq!(sweep().len(), 51);
}

#[test]
fn table2_multiport_rows_exact() {
    // The deterministic multiport cycle model reproduces the paper's
    // Table II load/store rows *exactly*.
    let r = sweep();
    for (n, ops) in [(32u32, 64u64), (64, 256), (128, 1024)] {
        let p = format!("transpose{n}");
        let c1 = get(r, &p, MemoryArchKind::mp_4r1w());
        assert_eq!(c1.report.stats.d_load_cycles, ops * 4);
        assert_eq!(c1.report.stats.store_cycles, ops * 16);
        let c2 = get(r, &p, MemoryArchKind::mp_4r2w());
        assert_eq!(c2.report.stats.d_load_cycles, ops * 4);
        assert_eq!(c2.report.stats.store_cycles, ops * 8);
    }
}

#[test]
fn table2_banked_write_efficiency_six_percent() {
    // "The write efficiencies are all ≈ 6%, which would correlate to a
    // 1:16 access ratio" — for the 16-bank LSB map at every size.
    let r = sweep();
    for n in [32, 64, 128] {
        let c = get(r, &format!("transpose{n}"), MemoryArchKind::banked(16));
        let eff = c.report.w_bank_eff().unwrap();
        assert!((0.055..0.07).contains(&eff), "n={n} eff={eff}");
    }
}

#[test]
fn table3_fft_op_counts_match_paper() {
    // D Load/Store and TW Load operation counts are the paper's exactly.
    let r = sweep();
    for (radix, d, tw) in [(4u32, 3072u64, 1920u64), (8, 2048, 1344), (16, 1536, 960)] {
        let c = get(r, &format!("fft4096r{radix}"), MemoryArchKind::banked(16));
        assert_eq!(c.report.stats.d_load_ops, d);
        assert_eq!(c.report.stats.store_ops, d);
        assert_eq!(c.report.stats.tw_load_ops, tw);
    }
}

#[test]
fn table3_16bank_offset_wins_fft() {
    // "The 16 bank memory, with the complex bank mapping, typically gives
    // us the highest performance."
    let r = sweep();
    for radix in [4u32, 8, 16] {
        let p = format!("fft4096r{radix}");
        let offset16 = get(r, &p, MemoryArchKind::banked_offset(16)).report.time_us();
        for arch in MemoryArchKind::table3_nine() {
            let t = get(r, &p, arch).report.time_us();
            assert!(
                offset16 <= t + 1e-9,
                "radix {radix}: 16-banks-offset {offset16:.2}us beaten by {arch} {t:.2}us"
            );
        }
    }
}

#[test]
fn table3_banked_ordering_more_banks_faster() {
    // More banks → more absolute performance (Table III, §VI).
    let r = sweep();
    for radix in [4u32, 8, 16] {
        let p = format!("fft4096r{radix}");
        for mapping in [
            |b| MemoryArchKind::banked(b),
            |b| MemoryArchKind::banked_offset(b),
        ] {
            let t16 = get(r, &p, mapping(16)).report.total_cycles();
            let t8 = get(r, &p, mapping(8)).report.total_cycles();
            let t4 = get(r, &p, mapping(4)).report.total_cycles();
            assert!(t16 <= t8 && t8 <= t4, "radix {radix}: {t16} {t8} {t4}");
        }
    }
}

#[test]
fn table3_offset_mapping_beats_lsb() {
    // The Offset map's raison d'être: interleaved complex data.
    let r = sweep();
    for radix in [4u32, 8, 16] {
        let p = format!("fft4096r{radix}");
        for banks in [4, 8, 16] {
            let lsb = get(r, &p, MemoryArchKind::banked(banks)).report.total_cycles();
            let off = get(r, &p, MemoryArchKind::banked_offset(banks)).report.total_cycles();
            assert!(off <= lsb, "radix {radix} banks {banks}: offset {off} vs lsb {lsb}");
        }
    }
}

#[test]
fn table3_vb_improves_on_1w() {
    // 4R-1W-VB: "improve write bandwidth on average to that of the 4R-2W
    // memory, but at the higher system speed of 771 MHz".
    let r = sweep();
    for radix in [4u32, 8, 16] {
        let p = format!("fft4096r{radix}");
        let t1w = get(r, &p, MemoryArchKind::mp_4r1w());
        let tvb = get(r, &p, MemoryArchKind::mp_4r1w_vb());
        let t2w = get(r, &p, MemoryArchKind::mp_4r2w());
        assert!(tvb.report.total_cycles() < t1w.report.total_cycles());
        assert_eq!(tvb.report.stats.store_cycles, t2w.report.stats.store_cycles);
        assert!(tvb.report.time_us() < t2w.report.time_us(), "VB wins on clock");
    }
}

#[test]
fn table3_tw_efficiency_low_like_paper() {
    // The shared W_N table's strided accesses: TW bank efficiencies sit
    // in the paper's 6–11% band for the LSB maps.
    let r = sweep();
    for radix in [4u32, 8, 16] {
        let c = get(r, &format!("fft4096r{radix}"), MemoryArchKind::banked(16));
        let eff = c.report.tw_bank_eff().unwrap();
        assert!((0.05..0.15).contains(&eff), "radix {radix}: TW eff {eff}");
    }
}

#[test]
fn table3_d_bank_efficiency_falls_with_fewer_banks() {
    let r = sweep();
    for radix in [4u32, 8, 16] {
        let p = format!("fft4096r{radix}");
        let e16 = get(r, &p, MemoryArchKind::banked(16)).report.r_bank_eff().unwrap();
        let e8 = get(r, &p, MemoryArchKind::banked(8)).report.r_bank_eff().unwrap();
        let e4 = get(r, &p, MemoryArchKind::banked(4)).report.r_bank_eff().unwrap();
        assert!(e16 >= e8 && e8 >= e4, "radix {radix}: {e16} {e8} {e4}");
    }
}

#[test]
fn fig9_shapes() {
    // Multiport footprint grows with capacity and hits its roofline;
    // banked footprint is flat; smaller banked = better perf/area.
    let r = sweep();
    let points = report::fig9_points(r);
    let fp = |arch: MemoryArchKind, kb: u32| {
        points
            .iter()
            .find(|p| p.arch == arch && p.size_kb == kb)
            .unwrap()
            .footprint
    };
    // 4R-1W: grows 64→112, unavailable past 112.
    assert!(fp(MemoryArchKind::mp_4r1w(), 64).unwrap().total_alms()
        < fp(MemoryArchKind::mp_4r1w(), 112).unwrap().total_alms());
    assert!(fp(MemoryArchKind::mp_4r1w(), 168).is_none());
    // Banked: flat across the grid.
    assert_eq!(
        fp(MemoryArchKind::banked_offset(16), 64).unwrap().total_alms(),
        fp(MemoryArchKind::banked_offset(16), 224).unwrap().total_alms()
    );
    // Perf/area: the 4-bank core beats the 16-bank core at 64 KB.
    let ppa = |arch: MemoryArchKind| {
        let p = points.iter().find(|p| p.arch == arch && p.size_kb == 64).unwrap();
        perf_per_area(p).unwrap()
    };
    assert!(ppa(MemoryArchKind::banked_offset(4)) > ppa(MemoryArchKind::banked_offset(16)));
}

#[test]
fn efficiency_comparable_to_cufft_band() {
    // §V: "The efficiency of our processor is up to 33% for the
    // multi-port memory version (27% for the banked memory version)" —
    // both ours land in the same band (15–40%).
    let r = sweep();
    let best_mp = MemoryArchKind::table3_nine()
        .into_iter()
        .filter(|a| !a.is_banked())
        .map(|a| get(r, "fft4096r16", a).report.compute_efficiency())
        .fold(0.0f64, f64::max);
    let best_banked = MemoryArchKind::table3_nine()
        .into_iter()
        .filter(|a| a.is_banked())
        .map(|a| get(r, "fft4096r16", a).report.compute_efficiency())
        .fold(0.0f64, f64::max);
    assert!((0.15..0.45).contains(&best_mp), "multiport eff {best_mp}");
    assert!((0.15..0.45).contains(&best_banked), "banked eff {best_banked}");
}

#[test]
fn renderers_produce_full_tables() {
    let r = sweep();
    let t2 = report::render_table2(r);
    assert!(t2.contains("128x128"));
    let t3 = report::render_table3(r);
    assert!(t3.contains("Radix 16"));
    let f9 = report::render_fig9(r);
    assert!(f9.lines().count() >= 11);
    let csv = report::sweep_csv(r);
    assert_eq!(csv.lines().count(), 52);
}
