//! Integration: replay parity — the core guarantee of the decoupled
//! simulator. A trace captured once (functionally, on a flat memory) and
//! replayed against an architecture's timing model must be
//! cycle-identical to the coupled `Machine::run_program` path on that
//! architecture, for every one of the paper's nine memories, on the
//! paper's benchmarks.

use soft_simt::coordinator::job::{BenchJob, TraceCache};
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::sim::replay::replay;

/// The ISSUE's parity matrix: 9 architectures × {32×32 transpose,
/// 4096-point FFT}. One trace per program; every cell replayed from it.
#[test]
fn replay_is_cycle_identical_across_all_nine_architectures() {
    for program in ["transpose32", "fft4096r16"] {
        let trace = BenchJob::new(program, MemoryArchKind::mp_4r1w())
            .capture_trace()
            .expect("functional execution succeeds");
        for arch in MemoryArchKind::table3_nine() {
            let job = BenchJob::new(program, arch);
            let coupled = job.run().expect("coupled run succeeds").report;
            let replayed = job.replay_trace(&trace).expect("replay succeeds").report;
            assert_eq!(
                replayed.total_cycles(),
                coupled.total_cycles(),
                "{program} on {arch}: elapsed"
            );
            assert_eq!(replayed.stats, coupled.stats, "{program} on {arch}: stats");
            assert_eq!(replayed.threads, coupled.threads);
            assert_eq!(replayed.arch, coupled.arch);
        }
    }
}

/// Parity must also hold on the exact (arbiter-stepped) banked timing
/// path, not just the closed-form fast path.
#[test]
fn replay_parity_holds_in_exact_timing_mode() {
    for arch in [
        MemoryArchKind::banked(16),
        MemoryArchKind::banked_offset(8),
        MemoryArchKind::banked(4),
    ] {
        let mut job = BenchJob::new("transpose64", arch);
        job.fast_timing = false;
        let trace = job.capture_trace().unwrap();
        let coupled = job.run().unwrap().report;
        let replayed = job.replay_trace(&trace).unwrap().report;
        assert_eq!(replayed.stats, coupled.stats, "{arch} (exact mode)");
        assert_eq!(replayed.total_cycles(), coupled.total_cycles());
    }
}

/// The cached sweep path (execute once, replay per architecture) must
/// reproduce the per-cell coupled sweep bit for bit, and must actually
/// share traces.
#[test]
fn cached_sweep_matches_coupled_sweep_on_paper_cells() {
    let mut jobs = Vec::new();
    for program in ["transpose32", "transpose128", "fft4096r8"] {
        for arch in MemoryArchKind::table3_nine() {
            jobs.push(BenchJob::new(program, arch));
        }
    }
    let runner = SweepRunner::default();
    let coupled = runner.run(&jobs).expect("coupled sweep");
    let cache = TraceCache::new();
    let cached = runner.run_with_cache(&jobs, &cache).expect("cached sweep");
    assert_eq!(cache.len(), 3, "27 cells must share 3 traces");
    assert_eq!(coupled.len(), cached.len());
    for (a, b) in coupled.iter().zip(&cached) {
        assert_eq!(a.job, b.job);
        assert_eq!(
            a.report.total_cycles(),
            b.report.total_cycles(),
            "{} on {}",
            a.job.program,
            a.job.arch
        );
        assert_eq!(a.report.stats, b.report.stats, "{} on {}", a.job.program, a.job.arch);
    }
}

/// A trace is portable across *capture* backends too: executing on a
/// banked or multiport machine's memory yields exactly the trace the
/// flat-memory capture produces (functional behaviour is
/// architecture-independent), and replaying a flat-captured trace
/// against a machine's own memory reproduces that machine's report.
#[test]
fn trace_capture_is_architecture_independent() {
    use soft_simt::programs::library::program_by_name;
    use soft_simt::sim::config::MachineConfig;
    use soft_simt::sim::machine::Machine;

    let job = BenchJob::new("fft4096r4", MemoryArchKind::mp_4r1w());
    let reference = job.capture_trace().unwrap();
    let workload = program_by_name("fft4096r4").unwrap();
    for arch in [MemoryArchKind::banked_offset(16), MemoryArchKind::mp_4r1w_vb()] {
        let mut cfg = MachineConfig::for_arch(arch)
            .with_mem_words(workload.mem_words())
            .with_fast_timing();
        if let Some(region) = workload.tw_region() {
            cfg = cfg.with_tw_region(region);
        }
        let mut machine = Machine::new(cfg.clone());
        workload.load_input(&mut machine, job.seed);
        let report = machine.run_program(workload.program()).unwrap();
        let as_run = machine.mem_trace().expect("facade captures the trace");
        assert_eq!(
            as_run, &reference,
            "trace must not depend on the memory it was captured on ({arch})"
        );
        // Replaying the flat-captured trace on this machine's memory
        // model reproduces the machine's own report.
        let replayed = replay(&reference, cfg.build_memory().as_ref(), cfg.max_cycles).unwrap();
        assert_eq!(replayed.total_cycles(), report.total_cycles(), "{arch}");
        assert_eq!(replayed.stats, report.stats, "{arch}");
    }
}
