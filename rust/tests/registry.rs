//! Registry integration suite (ISSUE 5): per-kernel analytical golden
//! models asserted against the functional executor, registry property
//! tests (every registered name parses, round-trips through `List`, and
//! builds a runnable program), and the no-stragglers guarantee — every
//! workload-name list in the crate is the registry, so none can drift.

use soft_simt::coordinator::job::BenchJob;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::programs::registry::{self, OpCountModel};
use soft_simt::service::{Request, Response, SimtEngine};

/// Extra (non-sweep) params per family, exercising the grammar bounds
/// the sweep members don't touch.
fn extra_params(family: &str) -> &'static [u32] {
    match family {
        "transpose" => &[4, 16, 256],
        "fft" => &[],
        "reduction" => &[32, 256],
        "scan" => &[64, 512],
        "histogram" => &[64, 512],
        "stencil" => &[64, 256],
        "gemm" => &[8, 16],
        "bitonic" => &[64, 2048],
        "spmv" => &[64, 2048],
        other => panic!("unknown family {other}"),
    }
}

/// The analytical golden model of every kernel family equals the
/// functional executor's trace, member by member — loads (data and
/// twiddle), stores and 16-wide FP ops, across sweep sizes and the
/// grammar extremes. This pins each kernel's shape independently of any
/// timing model.
#[test]
fn analytical_models_match_the_functional_executor() {
    for fam in registry::families() {
        let params: Vec<u32> =
            fam.sweep_params.iter().chain(extra_params(fam.family)).copied().collect();
        for param in params {
            let name = fam.name_of(param);
            let model = registry::model_by_name(&name).expect("registered members have models");
            let trace = BenchJob::new(name.clone(), MemoryArchKind::banked(16))
                .capture_trace()
                .unwrap_or_else(|e| panic!("{name} must execute: {e}"));
            let measured = OpCountModel::of_trace(&trace);
            assert_eq!(measured, model, "{name}: trace vs closed form");
            assert_eq!(trace.mem_op_count(), model.mem_ops(), "{name}: total memory ops");
        }
    }
}

/// The model also survives the full run pipeline: a coupled run's
/// reported op counts equal the closed form (one cheap member per
/// family).
#[test]
fn analytical_models_match_run_reports() {
    let members = [
        "transpose32", "fft4096r8", "reduction256", "scan256", "histogram256", "stencil256",
        "gemm16", "bitonic256", "spmv256",
    ];
    for name in members {
        let model = registry::model_by_name(name).expect("model");
        let r = BenchJob::new(name, MemoryArchKind::banked_offset(16)).run().unwrap();
        assert_eq!(r.report.stats.d_load_ops, model.d_load_ops, "{name} d loads");
        assert_eq!(r.report.stats.tw_load_ops, model.tw_load_ops, "{name} tw loads");
        assert_eq!(r.report.stats.store_ops, model.store_ops, "{name} stores");
        assert_eq!(r.report.stats.fp_cycles, model.fp_ops, "{name} fp ops");
    }
}

/// Every registered name parses, builds, and its workload agrees with
/// itself (name round-trip, power-of-two capacity, grammar bounds).
#[test]
fn every_registered_name_parses_and_builds() {
    let names = registry::program_names();
    assert!(names.len() >= 10, "expanded library: got {}", names.len());
    for name in &names {
        assert!(registry::is_known_program(name), "{name} must be known");
        let w = registry::program_by_name(name).expect("builds");
        assert_eq!(w.name(), name.as_str());
        assert!(w.mem_words().is_power_of_two());
        assert!(w.program().threads % 16 == 0, "{name}: warp-aligned thread blocks");
    }
    // Out-of-grammar neighbours of every family are rejected.
    for junk in [
        "transpose2048", "fft4096r2", "reduction8192", "scan32", "scan6144",
        "histogram8192", "stencil32", "gemm128", "gemm4", "scan", "gemm", "frobnicate",
        "bitonic32", "bitonic4096", "bitonic100", "spmv32", "spmv4096", "spmv",
    ] {
        assert!(!registry::is_known_program(junk), "{junk} must be rejected");
        assert!(registry::program_by_name(junk).is_none());
    }
}

/// Registered names round-trip through the service `List` and every
/// listed program actually runs end-to-end through the engine.
#[test]
fn list_round_trips_and_every_member_runs() {
    let engine = SimtEngine::new();
    let Response::List(listing) = engine.handle(&Request::List).unwrap() else {
        panic!("list answers list")
    };
    assert_eq!(listing.programs, registry::program_names());
    for name in &listing.programs {
        let resp = engine
            .handle(&Request::Run { program: name.clone(), mem: MemoryArchKind::banked(16) })
            .unwrap_or_else(|e| panic!("{name} must run: {e}"));
        let Response::Run(report) = resp else { panic!("run answers run") };
        assert_eq!(&report.program, name);
        assert!(report.total_cycles() > 0);
    }
}

/// No stragglers: every workload-name list in the crate enumerates the
/// registry. The sweep matrix, the service listing and the grammar can
/// therefore never silently drift apart.
#[test]
fn no_independent_workload_name_lists() {
    let registered = registry::program_names();

    // The benchmark matrix (`sweep --all`) is exactly the registry's
    // sweep members, with each family's declared arch slate.
    let jobs = BenchJob::extended_sweep();
    let mut matrix_names: Vec<String> = jobs.iter().map(|j| j.program.clone()).collect();
    matrix_names.dedup();
    assert_eq!(matrix_names, registered, "sweep matrix == registry enumeration");

    // The acceptance floor: 150 cells across 9 families (PR 9 added
    // the divergent bitonic + spmv rows).
    assert!(jobs.len() >= 150, "matrix cells: {}", jobs.len());
    let families: std::collections::HashSet<&str> = registered
        .iter()
        .map(|n| registry::parse(n).expect("registered names parse").0.family)
        .collect();
    assert!(families.len() >= 7, "kernel families: {}", families.len());

    // The service listing is the same enumeration.
    let Response::List(listing) = SimtEngine::new().handle(&Request::List).unwrap() else {
        panic!("list answers list")
    };
    assert_eq!(listing.programs, registered);

    // The paper half is exactly the paper families' members.
    for job in BenchJob::paper_sweep() {
        let (fam, _) = registry::parse(&job.program).expect("paper members parse");
        assert!(fam.paper, "{} in the paper sweep must be a paper family", job.program);
    }
}

/// The divergent kernels run end-to-end through the engine cold (trace
/// capture + reference replay) and warm (compiled replay off the session
/// cache) with identical reports — the lane masks recorded per memory op
/// carry the divergence through both replay paths bit for bit.
#[test]
fn divergent_kernels_run_cold_and_warm_through_the_engine() {
    let engine = SimtEngine::new();
    for name in ["bitonic256", "spmv256"] {
        let req = Request::Run { program: name.into(), mem: MemoryArchKind::mp_4r1w() };
        let Response::Run(cold) = engine.handle(&req).unwrap() else { panic!("run answers run") };
        let Response::Run(warm) = engine.handle(&req).unwrap() else { panic!("run answers run") };
        assert_eq!(cold.stats, warm.stats, "{name}: cold (reference) vs warm (compiled) stats");
        assert_eq!(cold.elapsed_cycles, warm.elapsed_cycles, "{name}: elapsed diverged");
        assert!(cold.total_cycles() > 0);

        let model = registry::model_by_name(name).expect("model");
        assert_eq!(cold.stats.d_load_ops, model.d_load_ops, "{name} d loads");
        assert_eq!(cold.stats.store_ops, model.store_ops, "{name} stores");
        assert_eq!(cold.stats.fp_cycles, model.fp_ops, "{name} fp ops");
    }
}

/// The new kernels flow through the design-space explorer like any
/// paper workload: one functional execution serves the whole parametric
/// space and the Pareto frontier is non-trivial.
#[test]
fn new_kernels_are_explorable() {
    use soft_simt::service::ExploreStrategy;
    let engine = SimtEngine::new();
    for program in ["scan1024", "histogram256", "gemm32"] {
        let resp = engine
            .handle(&Request::Explore {
                program: program.into(),
                strategy: ExploreStrategy::Halving,
            })
            .unwrap_or_else(|e| panic!("{program} must explore: {e}"));
        let Response::Explore(result) = resp else { panic!("explore answers explore") };
        assert!(!result.front.is_empty(), "{program}: empty frontier");
        assert!(result.points_total > 50, "{program}: {} points", result.points_total);
    }
}

/// The expanded matrix stays internally consistent when swept: every
/// extension cell replays from its family's shared trace, and the
/// distinct-workload count matches the registry enumeration.
#[test]
fn extended_sweep_runs_with_one_trace_per_member() {
    use soft_simt::coordinator::job::TraceCache;
    use soft_simt::coordinator::runner::SweepRunner;
    let jobs = BenchJob::extended_sweep();
    let cache = TraceCache::new();
    let results = SweepRunner::default().run_with_cache(&jobs, &cache).unwrap();
    assert_eq!(results.len(), jobs.len());
    assert_eq!(
        cache.len(),
        registry::program_names().len(),
        "one functional execution per registered member"
    );
    for r in &results {
        assert!(r.report.total_cycles() > 0, "{} on {}", r.job.program, r.job.arch);
    }
}
