//! Differential harness for the compiled-trace batch replayer (ISSUE 4
//! acceptance): `replay_many` over a [`CompiledTrace`] must be
//! `RunReport`-**identical** to the reference per-architecture
//! [`replay`] — every counter, not just totals — across
//!
//! - all nine paper architectures,
//! - random parametric explorer points (banks 2–32 × {LSB, OffsetN,
//!   XOR} × multiport port configs, including capacities small enough
//!   to engage the offset-shift clamp),
//! - random programs with random masks (ragged thread counts) and
//!   random strides, generated through the crate's own property-test
//!   harness (`util/proptest.rs`).

use soft_simt::coordinator::job::BenchJob;
use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::isa::inst::Instruction;
use soft_simt::isa::opcode::Opcode;
use soft_simt::isa::program::Program;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::mem::mapping::BankMapping;
use soft_simt::sim::compiled::{replay_compiled, replay_many, CompiledTrace};
use soft_simt::sim::exec::{execute, ExecParams, FlatMemory, MemTrace, SimError};
use soft_simt::sim::packed::{replay_many_packed, LaneChunk, ARCH_LANES};
use soft_simt::sim::replay::replay;
use soft_simt::sim::stats::RunReport;
use soft_simt::util::proptest::check;
use soft_simt::util::XorShift64;

/// Generate a random *memory-safe, divergence-free* program whose
/// address streams exercise the conflict maths: strided (`imuli` by a
/// random stride), offset, shifted and xor-mixed addresses, blocking and
/// non-blocking stores, and ragged thread counts (non-multiples of 16 →
/// partial lane masks in the trace).
fn random_program(rng: &mut XorShift64, mem_words: usize, max_len: usize) -> Program {
    let n = 2 + rng.below(max_len as u32) as usize;
    let addr_mask = (mem_words - 1) as u16;
    let mut insts = vec![Instruction::i(Opcode::Tid, 0, 0, 0)];
    for _ in 0..n {
        let r = |rng: &mut XorShift64| 1 + rng.below(30) as u8;
        let inst = match rng.below(12) {
            0 => Instruction::i(Opcode::Ldi, r(rng), 0, rng.next_u32() as u16),
            1 => Instruction::r(Opcode::Iadd, r(rng), r(rng), r(rng)),
            2 => Instruction::r(Opcode::Ixor, r(rng), r(rng), r(rng)),
            3 => Instruction::i(Opcode::Ishli, r(rng), r(rng), rng.below(6) as u16),
            4 => Instruction::r(Opcode::Fma, r(rng), r(rng), r(rng)),
            // Strided access: a = tid * stride (then masked into range).
            5 | 6 => {
                let a = r(rng);
                let stride = 1 + rng.below(33) as u16;
                insts.push(Instruction::i(Opcode::Imuli, a, 0, stride));
                insts.push(Instruction::i(Opcode::Iandi, a, a, addr_mask));
                Instruction::i(Opcode::Ld, r(rng), a, 0)
            }
            7 | 8 => {
                let a = r(rng);
                insts.push(Instruction::i(Opcode::Iandi, a, a, addr_mask));
                Instruction::i(Opcode::Ld, r(rng), a, 0)
            }
            9 | 10 => {
                let a = r(rng);
                insts.push(Instruction::i(Opcode::Iandi, a, a, addr_mask));
                let op = if rng.chance(0.5) { Opcode::St } else { Opcode::Stnb };
                Instruction::r(op, 0, a, r(rng))
            }
            _ => Instruction::i(Opcode::Iaddi, r(rng), r(rng), rng.next_u32() as u16),
        };
        insts.push(inst);
    }
    insts.push(Instruction::z(Opcode::Halt));
    // Ragged thread counts produce partial lane masks in the trace.
    let threads = 1 + rng.below(80);
    Program::new("diff-fuzz", threads, insts)
}

/// Extend a random program with *divergence gadgets* — self-contained
/// instruction sequences whose `bnz` outcomes split the block on
/// tid-derived (per-lane) predicates, yet always terminate:
///
/// - a **forward skip** over two filler instructions (if-shaped split,
///   reconverging at the branch's immediate post-dominator);
/// - a **masked store** (a store issued under the skip's half mask — the
///   trace records the divergent lane mask);
/// - a **bounded data-dependent loop** (1..=4 trips per lane, lanes
///   falling out over successive iterations).
///
/// Interleaved with the divergence-free generator's instruction mix, so
/// the resulting traces carry divergent masks *and* everything the base
/// fuzzer exercises.
fn random_divergent_program(rng: &mut XorShift64, mem_words: usize, max_len: usize) -> Program {
    let base = random_program(rng, mem_words, max_len);
    let addr_mask = (mem_words - 1) as u16;
    let mut insts: Vec<Instruction> = Vec::new();
    // Re-walk the base program, injecting gadgets between instructions
    // (dropping the base halt; we append our own).
    for &inst in base.insts[..base.insts.len() - 1].iter() {
        insts.push(inst);
        if !rng.chance(0.35) {
            continue;
        }
        let p = 1 + rng.below(30) as u8;
        match rng.below(3) {
            0 => {
                // Forward skip: lanes with tid bit set jump over 2 fillers.
                let bit = 1u16 << rng.below(3);
                insts.push(Instruction::i(Opcode::Iandi, p, 0, bit));
                let target = (insts.len() + 3) as u16;
                insts.push(Instruction::i(Opcode::Bnz, p, 0, target));
                insts.push(Instruction::i(Opcode::Iaddi, p, p, 1));
                insts.push(Instruction::i(Opcode::Ixori, p, p, 3));
            }
            1 => {
                // Masked store: half the lanes skip a strided store, so
                // the trace records a genuinely divergent lane mask.
                let a = 1 + rng.below(30) as u8;
                let stride = 1 + rng.below(9) as u16;
                insts.push(Instruction::i(Opcode::Iandi, p, 0, 1));
                let target = (insts.len() + 4) as u16;
                insts.push(Instruction::i(Opcode::Bnz, p, 0, target));
                insts.push(Instruction::i(Opcode::Imuli, a, 0, stride));
                insts.push(Instruction::i(Opcode::Iandi, a, a, addr_mask));
                insts.push(Instruction::r(Opcode::St, 0, a, p));
            }
            _ => {
                // Bounded loop: (tid & 3) + 1 trips, lanes retiring as
                // their counters hit zero — 1..=4 iterations, terminates.
                insts.push(Instruction::i(Opcode::Iandi, p, 0, 3));
                insts.push(Instruction::i(Opcode::Iaddi, p, p, 1));
                let body = insts.len() as u16;
                insts.push(Instruction::i(Opcode::Ixori, p, p, 8));
                insts.push(Instruction::i(Opcode::Ixori, p, p, 8));
                insts.push(Instruction::i(Opcode::Iaddi, p, p, 0xFFFF));
                insts.push(Instruction::i(Opcode::Bnz, p, 0, body));
            }
        }
    }
    insts.push(Instruction::z(Opcode::Halt));
    Program::new("diff-fuzz-div", base.threads, insts)
}

/// Capture the program's trace on a flat memory of `mem_words`, with a
/// random twiddle region half the time (so both load classes appear).
fn capture(rng: &mut XorShift64, program: &Program, mem_words: usize) -> MemTrace {
    let mut mem = FlatMemory::new(mem_words);
    let tw_region = if rng.chance(0.5) {
        Some((mem_words as u32 / 4)..(mem_words as u32 / 2))
    } else {
        None
    };
    let params = ExecParams { tw_region, max_cycles: 10_000_000, ..ExecParams::default() };
    execute(program, &mut mem, &params).expect("fuzz program executes")
}

fn assert_reports_identical(got: &RunReport, want: &RunReport, ctx: &str) {
    assert_eq!(got.stats, want.stats, "{ctx}: stats diverged");
    assert_eq!(got.elapsed_cycles, want.elapsed_cycles, "{ctx}: elapsed diverged");
    assert_eq!(got.program, want.program, "{ctx}");
    assert_eq!(got.arch, want.arch, "{ctx}");
    assert_eq!(got.threads, want.threads, "{ctx}");
}

fn random_parametric_arch(rng: &mut XorShift64) -> MemoryArchKind {
    let arch = if rng.chance(0.6) {
        MemoryArchKind::Banked {
            banks: [2u32, 4, 8, 16, 32][rng.below(5) as usize],
            mapping: match rng.below(3) {
                0 => BankMapping::Lsb,
                1 => BankMapping::Offset { shift: rng.below(BankMapping::MAX_SHIFT + 1) },
                _ => BankMapping::Xor,
            },
        }
    } else {
        let write_ports = 1 + rng.below(2);
        MemoryArchKind::MultiPort {
            read_ports: 1 << rng.below(4),
            write_ports,
            vb: write_ports == 1 && rng.chance(0.3),
        }
    };
    assert!(arch.is_valid(), "{arch:?}");
    arch
}

/// The core differential property: one random program, one trace, one
/// compiled trace — every candidate architecture charged three ways
/// (reference `replay`, single `replay_compiled`, batched `replay_many`)
/// must produce the identical `RunReport`.
#[test]
fn replay_many_identical_to_reference_on_random_programs() {
    check("replay_many == replay on random programs × archs", 30, |rng| {
        // Small capacities engage the offset-shift clamp (e.g. 32 banks
        // at 1 Ki words clamps shift 8 → 5); larger ones don't — both
        // sides must agree under either regime.
        let mem_words = 1usize << (10 + rng.below(4)); // 1 Ki .. 8 Ki words
        let program = random_program(rng, mem_words, 30);
        let trace = capture(rng, &program, mem_words);
        let compiled = CompiledTrace::compile(&trace);

        let mut archs = MemoryArchKind::table3_nine();
        for _ in 0..6 {
            archs.push(random_parametric_arch(rng));
        }
        let batch = replay_many(&compiled, &archs, u64::MAX);
        assert_eq!(batch.len(), archs.len());
        for (arch, batched) in archs.iter().zip(batch) {
            let mem = arch.build(mem_words);
            let reference = replay(&trace, mem.as_ref(), u64::MAX).expect("reference replays");
            let batched = batched.expect("compiled replay succeeds");
            assert_reports_identical(&batched, &reference, &format!("{arch} (batched)"));
            let single = replay_compiled(&compiled, *arch, u64::MAX).unwrap();
            assert_reports_identical(&single, &reference, &format!("{arch} (single)"));
        }
    });
}

/// Divergence differential (ISSUE 9): random *divergent* programs —
/// per-lane branch outcomes, masked stores, bounded data-dependent
/// loops — must charge bit-identically through all three replay paths
/// (reference `replay`, compiled `replay_many`, lane-packed
/// `replay_many_packed`) across the nine paper architectures plus random
/// parametric points. The per-op lane masks in the trace are the only
/// carrier of divergence, so this pins that every replayer honours them.
#[test]
fn divergent_programs_replay_identically_on_all_paths() {
    check("packed == scalar == reference on random divergent programs", 25, |rng| {
        let mem_words = 1usize << (10 + rng.below(4));
        let program = random_divergent_program(rng, mem_words, 20);
        let trace = capture(rng, &program, mem_words);
        let compiled = CompiledTrace::compile(&trace);

        let mut archs = MemoryArchKind::table3_nine();
        for _ in 0..4 {
            archs.push(random_parametric_arch(rng));
        }
        let scalar = replay_many(&compiled, &archs, u64::MAX);
        let packed = replay_many_packed(&compiled, &archs, u64::MAX);
        for ((arch, s), p) in archs.iter().zip(&scalar).zip(&packed) {
            let mem = arch.build(mem_words);
            let reference = replay(&trace, mem.as_ref(), u64::MAX).expect("reference replays");
            let s = s.as_ref().expect("scalar replay succeeds");
            let p = p.as_ref().expect("packed replay succeeds");
            assert_reports_identical(s, &reference, &format!("{arch} (scalar, divergent)"));
            assert_reports_identical(p, &reference, &format!("{arch} (packed, divergent)"));
        }
    });
}

/// The same property through the job layer the sweep runner and engine
/// use: `BenchJob::replay_compiled` vs the reference `replay_trace`, on
/// the paper's real workloads (FFT → twiddle loads + blocking stores;
/// transpose → non-blocking stores).
#[test]
fn job_layer_compiled_replay_matches_reference_on_paper_workloads() {
    for program in ["transpose64", "fft4096r8"] {
        let trace = BenchJob::new(program, MemoryArchKind::mp_4r1w())
            .capture_trace()
            .expect("paper workload captures");
        let compiled = CompiledTrace::compile(&trace);
        for arch in MemoryArchKind::table3_nine() {
            let job = BenchJob::new(program, arch);
            let reference = job.replay_trace(&trace).unwrap().report;
            let fast = job.replay_compiled(&compiled).unwrap().report;
            assert_reports_identical(&fast, &reference, &format!("{program} on {arch}"));
            // And both equal the coupled simulator (the transitive
            // anchor replay_parity.rs pins for the reference path).
            let coupled = job.run().unwrap().report;
            assert_reports_identical(&fast, &coupled, &format!("{program} on {arch} (coupled)"));
        }
    }
}

/// Wbuf-stall accounting (ISSUE 4 satellite): the saturating stall
/// arithmetic must agree between the two replayers on store-heavy
/// random programs, and a cost-1 non-blocking stream counts zero.
#[test]
fn wbuf_stall_accounting_agrees_between_replayers() {
    check("wbuf stalls identical across replay paths", 20, |rng| {
        let mem_words = 4096;
        // Store-heavy program: high chance of stnb streams.
        let mut insts = vec![Instruction::i(Opcode::Tid, 0, 0, 0)];
        for _ in 0..20 {
            let stride = 1 + rng.below(17) as u16;
            insts.push(Instruction::i(Opcode::Imuli, 1, 0, stride));
            insts.push(Instruction::i(Opcode::Iandi, 1, 1, (mem_words - 1) as u16));
            let op = if rng.chance(0.8) { Opcode::Stnb } else { Opcode::St };
            insts.push(Instruction::r(op, 0, 1, 0));
        }
        insts.push(Instruction::z(Opcode::Halt));
        let program = Program::new("wbuf-fuzz", 16 * (1 + rng.below(64)), insts);
        let trace = capture(rng, &program, mem_words);
        let compiled = CompiledTrace::compile(&trace);
        for arch in [MemoryArchKind::banked(16), MemoryArchKind::mp_4r1w()] {
            let mem = arch.build(mem_words);
            let reference = replay(&trace, mem.as_ref(), u64::MAX).unwrap();
            let fast = replay_compiled(&compiled, arch, u64::MAX).unwrap();
            let (f, r) = (&fast.stats, &reference.stats);
            assert_eq!(f.wbuf_stall_cycles, r.wbuf_stall_cycles, "{arch}");
            assert_eq!(f.drain_cycles, r.drain_cycles, "{arch}");
        }
    });
}

/// ISSUE 6 tentpole property: the lane-packed kernel (sequential and
/// BSP-parallel drivers) must be `RunReport`-bit-identical to the scalar
/// `replay_many` — which the property above pins to the reference
/// `replay` — across random programs × paper + parametric architectures.
#[test]
fn packed_replay_identical_to_scalar_on_random_programs() {
    let runner = SweepRunner::new(3);
    check("packed == scalar replay_many on random programs × archs", 25, |rng| {
        let mem_words = 1usize << (10 + rng.below(4));
        let program = random_program(rng, mem_words, 30);
        let trace = capture(rng, &program, mem_words);
        let compiled = CompiledTrace::compile(&trace);

        let mut archs = MemoryArchKind::table3_nine();
        for _ in 0..6 {
            archs.push(random_parametric_arch(rng));
        }
        let scalar = replay_many(&compiled, &archs, u64::MAX);
        let packed = replay_many_packed(&compiled, &archs, u64::MAX);
        let parallel = runner.replay_many_parallel(&compiled, &archs, u64::MAX);
        assert_eq!(packed.len(), scalar.len());
        assert_eq!(parallel.len(), scalar.len());
        for ((arch, s), (p, w)) in archs.iter().zip(&scalar).zip(packed.iter().zip(&parallel)) {
            let s = s.as_ref().expect("scalar replay succeeds");
            let p = p.as_ref().expect("packed replay succeeds");
            let w = w.as_ref().expect("parallel replay succeeds");
            assert_reports_identical(p, s, &format!("{arch} (packed)"));
            assert_reports_identical(w, s, &format!("{arch} (wavefront)"));
        }
    });
}

/// ISSUE 6 satellite: segmented replay with *random split points* —
/// chunks suspended and resumed at every seam — must stitch
/// bit-identically to the whole-trace walk, across random programs ×
/// paper + parametric architectures.
#[test]
fn segmented_replay_with_random_splits_is_bit_identical() {
    check("random-seam segmented replay == whole-trace replay", 20, |rng| {
        let mem_words = 1usize << (10 + rng.below(4));
        let program = random_program(rng, mem_words, 40);
        let trace = capture(rng, &program, mem_words);
        let compiled = CompiledTrace::compile(&trace);
        let mut archs = MemoryArchKind::table3_nine();
        for _ in 0..4 {
            archs.push(random_parametric_arch(rng));
        }
        let whole = replay_many(&compiled, &archs, u64::MAX);

        // Random instruction-boundary split points (possibly none,
        // possibly adjacent — zero-length segments must be harmless).
        let n = compiled.n_instrs();
        let mut splits: Vec<usize> = (0..rng.below(6)).map(|_| rng.below(n as u32 + 1) as usize).collect();
        splits.push(0);
        splits.push(n);
        splits.sort_unstable();

        let segmented: Vec<_> = archs
            .chunks(ARCH_LANES)
            .flat_map(|slate| {
                let mut chunk = LaneChunk::new(&compiled, slate);
                for pair in splits.windows(2) {
                    chunk.advance(&compiled, pair[0]..pair[1]);
                    // Cross the seam: suspend, rebuild from scratch,
                    // resume — exactly what a worker handoff carries.
                    let seam = chunk.suspend();
                    let mut fresh = LaneChunk::new(&compiled, slate);
                    fresh.resume(&seam);
                    chunk = fresh;
                }
                chunk.finish(&compiled, u64::MAX)
            })
            .collect();
        assert_eq!(segmented.len(), whole.len());
        for ((arch, s), w) in archs.iter().zip(&segmented).zip(&whole) {
            let s = s.as_ref().expect("segmented replay succeeds");
            let w = w.as_ref().expect("whole replay succeeds");
            assert_reports_identical(s, w, &format!("{arch} (seamed)"));
        }
    });
}

/// ISSUE 6 satellite: non-multiple-of-8 slates — every remainder-chunk
/// width from 1 to a full chunk plus one — keep padding lanes inert.
#[test]
fn remainder_lane_slates_match_scalar() {
    let mut rng = XorShift64::new(0x8EA1);
    let mem_words = 2048;
    let program = random_program(&mut rng, mem_words, 25);
    let trace = capture(&mut rng, &program, mem_words);
    let compiled = CompiledTrace::compile(&trace);
    let pool: Vec<MemoryArchKind> = {
        let mut v = MemoryArchKind::table3_nine();
        for _ in 0..3 {
            v.push(random_parametric_arch(&mut rng));
        }
        v
    };
    for width in 1..=ARCH_LANES + 1 {
        let slate: Vec<MemoryArchKind> = pool.iter().copied().take(width).collect();
        let packed = replay_many_packed(&compiled, &slate, u64::MAX);
        let scalar = replay_many(&compiled, &slate, u64::MAX);
        assert_eq!(packed.len(), width);
        for ((arch, p), s) in slate.iter().zip(&packed).zip(&scalar) {
            assert_reports_identical(
                p.as_ref().unwrap(),
                s.as_ref().unwrap(),
                &format!("{arch} (slate width {width})"),
            );
        }
    }
}

/// Cycle-limit verdicts must agree per architecture, and a failing
/// candidate must not disturb its batch-mates.
#[test]
fn cycle_limit_verdicts_agree_and_stay_isolated() {
    let mut rng = XorShift64::new(0xD1FF);
    let mem_words = 1024;
    let program = random_program(&mut rng, mem_words, 40);
    let trace = capture(&mut rng, &program, mem_words);
    let compiled = CompiledTrace::compile(&trace);
    // Pick a limit between the fastest and slowest candidate so the
    // batch genuinely mixes verdicts (unless the trace is so small that
    // all candidates agree — then the equality check still holds).
    let archs = MemoryArchKind::table3_nine();
    let cycles: Vec<u64> = archs
        .iter()
        .map(|&a| replay_compiled(&compiled, a, u64::MAX).unwrap().total_cycles())
        .collect();
    let limit = (cycles.iter().min().unwrap() + cycles.iter().max().unwrap()) / 2;
    let batch = replay_many(&compiled, &archs, limit);
    // The lane-packed kernel checks the limit once per lane at the end
    // of the walk (the clock is monotone), yet must reach the very same
    // per-arch verdicts as the per-instruction reference checks.
    let packed = replay_many_packed(&compiled, &archs, limit);
    for ((arch, p), b) in archs.iter().zip(&packed).zip(&batch) {
        match (p, b) {
            (Ok(a), Ok(b)) => assert_reports_identical(a, b, &format!("{arch} (packed @ limit)")),
            (Err(SimError::CycleLimit { limit: la }), Err(SimError::CycleLimit { limit: lb })) => {
                assert_eq!(la, lb);
            }
            other => panic!("{arch}: packed verdict diverged from scalar: {other:?}"),
        }
    }
    for ((arch, batched), exact) in archs.iter().zip(&batch).zip(&cycles) {
        let mem = arch.build(mem_words);
        let reference = replay(&trace, mem.as_ref(), limit);
        match (batched, &reference) {
            (Ok(a), Ok(b)) => {
                assert_reports_identical(a, b, &arch.label());
                assert!(a.total_cycles() == *exact);
            }
            (Err(SimError::CycleLimit { limit: la }), Err(SimError::CycleLimit { limit: lb })) => {
                assert_eq!(la, lb);
            }
            other => panic!("{arch}: verdicts diverged: {other:?}"),
        }
    }
}
