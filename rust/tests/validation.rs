//! Integration: full validation matrix — every program × every memory
//! architecture, against host references and (when built) the PJRT golden
//! models.

use soft_simt::coordinator::validate;
use soft_simt::runtime::ArtifactRuntime;

fn runtime() -> Option<ArtifactRuntime> {
    let rt = ArtifactRuntime::from_env().ok()?;
    rt.has_artifact("fft4096").then_some(rt)
}

#[test]
fn all_transposes_all_archs() {
    let rt = runtime();
    let checks = validate::validate_transposes(rt.as_ref());
    assert_eq!(checks.len(), 3 * 8);
    for c in &checks {
        assert!(c.passed, "{}: {}", c.name, c.detail);
    }
}

#[test]
fn all_ffts_all_archs() {
    let rt = runtime();
    let checks = validate::validate_ffts(rt.as_ref());
    assert_eq!(checks.len(), 3 * 9);
    for c in &checks {
        assert!(c.passed, "{}: {}", c.name, c.detail);
    }
}

#[test]
fn all_registry_workloads_all_archs() {
    use soft_simt::programs::registry;
    let rt = runtime();
    let checks = validate::validate_workloads(rt.as_ref());
    // One check per (extension member × validation arch): every
    // non-paper registry member (the paper families keep their
    // specialized validators, so nothing is simulated twice), on the
    // paper nine + three parametric extremes.
    let extension_members: usize = registry::families()
        .iter()
        .filter(|f| !f.paper)
        .map(|f| f.sweep_params.len())
        .sum();
    assert!(extension_members >= 7, "got {extension_members}");
    assert_eq!(checks.len(), extension_members * validate::workload_validation_archs().len());
    for c in &checks {
        assert!(c.passed, "{}: {}", c.name, c.detail);
    }
}

#[test]
fn conflict_oracle_cross_check() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for c in validate::validate_conflict_oracle(&rt, 0xAB) {
        assert!(c.passed, "{}: {}", c.name, c.detail);
    }
}
