//! Integration: the Rust ⇄ PJRT bridge against the AOT artifacts.
//!
//! Requires `make artifacts` (the tests skip politely when the artifacts
//! are missing, so `cargo test` stays green on a fresh checkout; `make
//! test` runs the full path).

use soft_simt::mem::conflict::max_conflicts;
use soft_simt::mem::mapping::{BankMap, BankMapping};
use soft_simt::mem::{FULL_MASK, LANES};
use soft_simt::programs::fft::reference_fft;
use soft_simt::runtime::golden::{conflict_oracle, golden_fft, golden_transpose};
use soft_simt::runtime::ArtifactRuntime;
use soft_simt::util::XorShift64;

fn runtime_or_skip(artifact: &str) -> Option<ArtifactRuntime> {
    let rt = ArtifactRuntime::from_env().expect("PJRT CPU client");
    if rt.has_artifact(artifact) {
        Some(rt)
    } else {
        eprintln!("skipping: artifacts/{artifact}.hlo.txt not built (run `make artifacts`)");
        None
    }
}

#[test]
fn golden_fft_matches_host_reference() {
    let Some(rt) = runtime_or_skip("fft4096") else { return };
    let mut rng = XorShift64::new(0xFACE);
    let re = rng.f32_vec(4096);
    let im = rng.f32_vec(4096);
    let (gr, gi) = golden_fft(&rt, &re, &im).expect("fft artifact executes");
    let (hr, hi) = reference_fft(&re, &im);
    let max_mag = hr
        .iter()
        .zip(&hi)
        .map(|(r, i)| (r * r + i * i).sqrt())
        .fold(0.0f64, f64::max);
    for k in 0..4096 {
        let err = ((gr[k] as f64 - hr[k]).powi(2) + (gi[k] as f64 - hi[k]).powi(2)).sqrt();
        assert!(
            err / max_mag < 1e-5,
            "k={k}: pjrt ({}, {}) vs host ({}, {})",
            gr[k],
            gi[k],
            hr[k],
            hi[k]
        );
    }
}

#[test]
fn golden_fft_impulse_is_flat() {
    let Some(rt) = runtime_or_skip("fft4096") else { return };
    let mut re = vec![0.0f32; 4096];
    re[0] = 1.0;
    let im = vec![0.0f32; 4096];
    let (gr, gi) = golden_fft(&rt, &re, &im).unwrap();
    for k in 0..4096 {
        assert!((gr[k] - 1.0).abs() < 1e-5 && gi[k].abs() < 1e-5, "k={k}");
    }
}

#[test]
fn golden_transposes_match_host() {
    for n in [32usize, 64, 128] {
        let Some(rt) = runtime_or_skip(&format!("transpose{n}")) else { return };
        let mut rng = XorShift64::new(n as u64);
        let x = rng.f32_vec(n * n);
        let y = golden_transpose(&rt, n, &x).expect("transpose artifact executes");
        for i in 0..n {
            for j in 0..n {
                assert_eq!(y[j * n + i], x[i * n + j], "n={n} ({i},{j})");
            }
        }
    }
}

#[test]
fn conflict_oracle_agrees_with_cycle_accurate_model() {
    // The L1 Pallas kernel and the L3 controller must compute identical
    // conflict counts — the analytical timing mode depends on it.
    for banks in [4u32, 8, 16] {
        let Some(rt) = runtime_or_skip(&format!("conflict{banks}")) else { return };
        let mut rng = XorShift64::new(banks as u64 * 7919);
        let ops: Vec<[u32; LANES]> = (0..600) // non-multiple of the batch: exercises padding
            .map(|_| {
                let mut a = [0u32; LANES];
                for x in a.iter_mut() {
                    *x = rng.below(1 << 16);
                }
                a
            })
            .collect();
        for mapping in [BankMapping::Lsb, BankMapping::offset()] {
            let map = BankMap::new(banks, mapping);
            let oracle =
                conflict_oracle(&rt, banks, &ops, mapping.shift()).expect("oracle executes");
            assert_eq!(oracle.len(), ops.len());
            for (i, (op, &o)) in ops.iter().zip(&oracle).enumerate() {
                let l3 = max_conflicts(op, FULL_MASK, &map);
                assert_eq!(o, l3, "banks={banks} {mapping:?} op {i}");
            }
        }
    }
}

#[test]
fn conflict_oracle_extremes() {
    let Some(rt) = runtime_or_skip("conflict16") else { return };
    // All-same addresses: 16 conflicts. Consecutive: 1.
    let same = [[7u32; LANES]; 1];
    let mut consec = [[0u32; LANES]; 1];
    for (l, a) in consec[0].iter_mut().enumerate() {
        *a = l as u32;
    }
    assert_eq!(conflict_oracle(&rt, 16, &same, 0).unwrap(), vec![16]);
    assert_eq!(conflict_oracle(&rt, 16, &consec, 0).unwrap(), vec![1]);
}
