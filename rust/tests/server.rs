//! Server-layer integration suite: many sessions over one shared
//! engine. Pins the ISSUE's concurrency guarantees — exactly-once
//! functional execution per distinct workload under racing clients,
//! per-session error isolation, warm trace reads taking no shard write
//! lock, wire-level backpressure rejection, and real TCP / Unix-socket
//! round-trips through `SocketServer`.

use soft_simt::coordinator::runner::SweepRunner;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::obs::Counter;
use soft_simt::server::{Dispatcher, ListenAddr, Session, SocketServer};
use soft_simt::service::wire;
use soft_simt::service::{Request, Response, ServiceError, SimtEngine, StatsScope};
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

fn shared_engine() -> Arc<SimtEngine> {
    Arc::new(SimtEngine::with_runner(SweepRunner::new(4)))
}

fn run_req(program: &str, mem: MemoryArchKind) -> Request {
    Request::Run { program: program.into(), mem }
}

fn session_stats(s: &Session) -> soft_simt::obs::MetricsSnapshot {
    match s.handle(&Request::Stats { scope: StatsScope::Session }) {
        Ok(Response::Stats(snap)) => snap,
        other => panic!("session stats: {other:?}"),
    }
}

/// M threads × K requests over one engine: every distinct workload is
/// functionally executed exactly once no matter how the sessions race
/// on the cold keys — the single-flight store guarantee, observed
/// end to end.
#[test]
fn racing_sessions_capture_each_workload_exactly_once() {
    let engine = shared_engine();
    let archs = MemoryArchKind::table3_nine();
    std::thread::scope(|scope| {
        for t in 0..4 {
            let engine = Arc::clone(&engine);
            let archs = &archs;
            scope.spawn(move || {
                let session = Session::new(engine);
                for k in 0..4 {
                    let program = if (t + k) % 2 == 0 { "transpose32" } else { "transpose64" };
                    let resp = session.handle(&run_req(program, archs[(t + k) % archs.len()]));
                    assert!(resp.is_ok(), "{program}: {:?}", resp.err());
                }
                assert_eq!(session_stats(&session).counter("requests.served"), Some(4));
            });
        }
    });
    // Two distinct workloads ever requested → exactly two captures,
    // regardless of which of the 16 racing requests arrived cold.
    assert_eq!(engine.functional_executions(), 2);
    assert_eq!(engine.cache().len(), 2);
    assert_eq!(engine.metrics().get(Counter::SessionsOpened), 4);
    assert_eq!(engine.metrics().get(Counter::RequestsServed), 16);
}

/// One client's failure lands on its own books (and the engine's) —
/// never on a neighbour session's.
#[test]
fn session_errors_are_isolated() {
    let engine = shared_engine();
    let a = Session::new(Arc::clone(&engine));
    let b = Session::new(Arc::clone(&engine));
    let err = a.handle(&run_req("no-such-kernel", MemoryArchKind::banked(16))).unwrap_err();
    assert!(matches!(err, ServiceError::UnknownProgram(_)));
    a.handle(&run_req("transpose32", MemoryArchKind::banked(16))).unwrap();
    b.handle(&run_req("transpose32", MemoryArchKind::banked(4))).unwrap();

    let sa = session_stats(&a);
    let sb = session_stats(&b);
    assert_eq!(sa.counter("requests.errors"), Some(1), "a owns its failure");
    assert_eq!(sa.counter("requests.served"), Some(2));
    assert_eq!(sb.counter("requests.errors"), Some(0), "b never sees a's failure");
    assert_eq!(sb.counter("requests.served"), Some(1));
    assert_eq!(engine.metrics().get(Counter::RequestsErrors), 1);
    // The shared economy still held: one capture for both sessions.
    assert_eq!(engine.functional_executions(), 1);
}

/// The ISSUE's acceptance check: once a workload is captured (and its
/// compiled form built), concurrent warm traffic takes zero shard
/// write locks — reads scale like the paper's banked loads.
#[test]
fn warm_traffic_takes_no_shard_write_lock() {
    let engine = shared_engine();
    // Cold capture, then a second run to build the compiled trace.
    engine.handle(&run_req("transpose32", MemoryArchKind::banked(16))).unwrap();
    engine.handle(&run_req("transpose32", MemoryArchKind::mp_4r1w())).unwrap();
    let cold_locks = engine.metrics().get(Counter::StoreShardWriteLocks);
    assert!(cold_locks >= 1, "the cold path must have installed cells");

    let archs = MemoryArchKind::table3_nine();
    std::thread::scope(|scope| {
        for t in 0..4 {
            let engine = Arc::clone(&engine);
            let archs = &archs;
            scope.spawn(move || {
                let session = Session::new(engine);
                for k in 0..8 {
                    session.handle(&run_req("transpose32", archs[(t + k) % archs.len()])).unwrap();
                }
            });
        }
    });
    assert_eq!(
        engine.metrics().get(Counter::StoreShardWriteLocks),
        cold_locks,
        "32 warm runs across 4 sessions acquired zero shard write locks"
    );
    assert_eq!(engine.functional_executions(), 1);
}

/// Wire-level backpressure: past the dispatcher depth a line is
/// answered `{"ok":false,...,"exit_code":3}` without being decoded,
/// and the rejection is counted server-wide.
#[test]
fn serve_rejects_lines_past_the_dispatcher_depth() {
    let engine = shared_engine();
    let dispatcher = Dispatcher::new(0, Arc::clone(engine.metrics()));
    let session = Session::new(Arc::clone(&engine));
    let input = "{\"op\":\"list\"}\nthis line is never even decoded\n";
    let mut output = Vec::new();
    wire::serve_with(&session, Some(&dispatcher), input.as_bytes(), &mut output).unwrap();
    let text = String::from_utf8(output).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "every line answered in-band:\n{text}");
    for line in &lines {
        assert!(line.contains("\"ok\":false"), "{line}");
        assert!(line.contains("\"exit_code\":3"), "retryable overload class: {line}");
        assert!(line.contains("overloaded"), "{line}");
    }
    assert_eq!(engine.metrics().get(Counter::OverloadRejections), 2);
    assert_eq!(engine.metrics().get(Counter::RequestsServed), 0, "nothing reached the engine");

    // With one slot the sequential loop admits every line in turn: the
    // permit is released when the line's reply is written.
    let dispatcher = Dispatcher::new(1, Arc::clone(engine.metrics()));
    let mut output = Vec::new();
    wire::serve_with(&session, Some(&dispatcher), "{\"op\":\"list\"}\n".as_bytes(), &mut output)
        .unwrap();
    let text = String::from_utf8(output).unwrap();
    assert!(text.contains("\"ok\":true"), "{text}");
    assert_eq!(dispatcher.in_flight(), 0);
}

/// Batch-shape pins for `Session::handle_batch`'s `split_inclusive`
/// segmentation: an *empty* batch line answers with an empty array (no
/// panic, nothing served), and a batch that *starts* with a `Stats`
/// barrier answers it first, in request order, before the concurrent
/// remainder.
#[test]
fn empty_and_stats_first_batches_answer_in_shape() {
    let engine = shared_engine();
    let session = Session::new(Arc::clone(&engine));

    // Empty batch: zero segments, zero responses.
    assert!(session.handle_batch(&[]).is_empty());
    assert_eq!(session_stats(&session).counter("requests.served"), Some(1), "only the stats probe");

    // Stats-first batch: the barrier is the whole first segment (its
    // concurrent prefix is empty — the `[] => {}` arm), and the run
    // behind it still executes.
    let batch = [
        Request::Stats { scope: StatsScope::Session },
        run_req("transpose32", MemoryArchKind::banked(16)),
    ];
    let replies = session.handle_batch(&batch);
    assert_eq!(replies.len(), 2);
    assert!(matches!(replies[0], Ok(Response::Stats(_))), "stats answered first: {replies:?}");
    assert!(matches!(replies[1], Ok(Response::Run(_))), "run answered second: {replies:?}");

    // Same shapes through the wire: "[]" answers "[]" on its own line.
    let mut output = Vec::new();
    let input = "[]\n[{\"op\":\"stats\"},{\"op\":\"list\"}]\n";
    wire::serve_with(&session, None, input.as_bytes(), &mut output).unwrap();
    let text = String::from_utf8(output).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "{text}");
    assert_eq!(lines[0], "[]", "empty batch answers an empty array");
    assert!(lines[1].starts_with("[{\"ok\":true,\"op\":\"stats\""), "{}", lines[1]);
    assert!(lines[1].contains("\"op\":\"list\""), "{}", lines[1]);
}

fn drive_client<S: std::io::Read + Write>(stream: S) -> Vec<String> {
    let mut reader = BufReader::new(stream);
    let mut replies = Vec::new();
    for line in [
        "{\"op\":\"list\"}",
        "{\"op\":\"run\",\"program\":\"transpose32\",\"mem\":\"16-banks\"}",
        "[{\"op\":\"stats\",\"scope\":\"session\"},{\"op\":\"stats\"}]",
    ] {
        reader.get_mut().write_all(line.as_bytes()).unwrap();
        reader.get_mut().write_all(b"\n").unwrap();
        reader.get_mut().flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        replies.push(reply.trim_end().to_string());
    }
    replies
}

fn assert_client_replies(replies: &[String]) {
    assert_eq!(replies.len(), 3);
    assert!(replies[0].contains("\"ok\":true") && replies[0].contains("\"op\":\"list\""));
    assert!(replies[1].contains("\"total_cycles\":"), "{}", replies[1]);
    assert!(
        replies[2].contains("\"scope\":\"session\"") && replies[2].contains("\"scope\":\"engine\""),
        "both stats scopes answered on one batch line: {}",
        replies[2]
    );
    assert!(!replies.iter().any(|r| r.contains("\"ok\":false")), "{replies:?}");
}

/// Two real TCP clients of one `serve --listen` server, lock-step
/// request/reply — the socket front-end satellite, end to end.
#[test]
fn tcp_clients_share_one_engine() {
    let engine = shared_engine();
    let addr = ListenAddr::parse("127.0.0.1:0").unwrap();
    let server = SocketServer::bind(Arc::clone(&engine), &addr, 8).unwrap();
    let local = server.local_addr().unwrap();
    // The accept loop runs for the rest of the process; the test talks
    // to it and exits (clients disconnect cleanly when dropped).
    std::thread::spawn(move || {
        let _ = server.run();
    });

    let clients: Vec<_> = (0..2)
        .map(|_| {
            std::thread::spawn({
                let local = local.clone();
                move || drive_client(std::net::TcpStream::connect(&local).unwrap())
            })
        })
        .collect();
    for client in clients {
        assert_client_replies(&client.join().unwrap());
    }
    assert!(engine.metrics().get(Counter::SessionsOpened) >= 2);
    assert_eq!(engine.functional_executions(), 1, "both clients shared one capture");
}

/// The same transport over a Unix domain socket.
#[cfg(unix)]
#[test]
fn unix_socket_client_roundtrips() {
    let engine = shared_engine();
    let path = std::env::temp_dir().join(format!("soft-simt-test-{}.sock", std::process::id()));
    let addr = ListenAddr::parse(&format!("unix:{}", path.display())).unwrap();
    let server = SocketServer::bind(Arc::clone(&engine), &addr, 8).unwrap();
    assert_eq!(server.local_addr().unwrap(), path.display().to_string());
    std::thread::spawn(move || {
        let _ = server.run();
    });

    let replies = drive_client(std::os::unix::net::UnixStream::connect(&path).unwrap());
    assert_client_replies(&replies);
    assert!(engine.metrics().get(Counter::SessionsOpened) >= 1);
    let _ = std::fs::remove_file(&path);
}
