//! Integration: the analytical timing mode (L1 Pallas conflict kernel via
//! PJRT) must reproduce the cycle-accurate simulator's attributed memory
//! cycles exactly — same conflict maths, same §III-A overhead model.

use soft_simt::coordinator::job::BenchJob;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::programs::library::{program_by_name, Workload};
use soft_simt::runtime::analytical::{estimate_banked, estimate_multiport};
use soft_simt::runtime::ArtifactRuntime;
use soft_simt::sim::config::MachineConfig;
use soft_simt::sim::machine::Machine;
use soft_simt::util::XorShift64;

fn traced_run(
    program: &str,
    arch: MemoryArchKind,
) -> (Machine, soft_simt::sim::stats::RunReport) {
    let workload = program_by_name(program).unwrap();
    let mut cfg = MachineConfig::for_arch(arch)
        .with_mem_words(workload.mem_words())
        .with_fast_timing()
        .with_mem_trace();
    if let Some(region) = workload.tw_region() {
        cfg = cfg.with_tw_region(region);
    }
    let mut m = Machine::new(cfg);
    let mut rng = XorShift64::new(0x5EED);
    match &workload {
        Workload::Transpose(plan, _) => {
            let src: Vec<u32> = (0..plan.n * plan.n).map(|_| rng.next_u32()).collect();
            m.load_image(plan.src_base, &src);
        }
        Workload::Fft(plan, _) => {
            let data = rng.f32_vec(2 * plan.n as usize);
            m.load_f32_image(plan.data_base, &data);
            m.load_f32_image(plan.tw_base, &plan.twiddles);
        }
    }
    let r = m.run_program(workload.program()).unwrap();
    (m, r)
}

#[test]
fn analytical_banked_equals_simulator() {
    let rt = ArtifactRuntime::from_env().unwrap();
    if !rt.has_artifact("conflict16") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for program in ["transpose32", "fft4096r16"] {
        for arch in [
            MemoryArchKind::banked(16),
            MemoryArchKind::banked_offset(16),
            MemoryArchKind::banked(4),
            MemoryArchKind::banked_offset(8),
        ] {
            let (m, report) = traced_run(program, arch);
            let est = estimate_banked(&rt, arch, m.mem_trace()).expect("oracle scores trace");
            assert_eq!(
                est.load_cycles,
                report.stats.load_cycles(),
                "{program} on {arch}: loads"
            );
            assert_eq!(
                est.store_cycles, report.stats.store_cycles,
                "{program} on {arch}: stores"
            );
        }
    }
}

#[test]
fn analytical_multiport_equals_simulator() {
    for program in ["transpose64", "fft4096r4"] {
        for arch in [
            MemoryArchKind::mp_4r1w(),
            MemoryArchKind::mp_4r2w(),
            MemoryArchKind::mp_4r1w_vb(),
        ] {
            let (m, report) = traced_run(program, arch);
            let est = estimate_multiport(arch, m.mem_trace()).unwrap();
            assert_eq!(est.load_cycles, report.stats.load_cycles(), "{program} on {arch}");
            assert_eq!(est.store_cycles, report.stats.store_cycles, "{program} on {arch}");
        }
    }
}

#[test]
fn trace_shapes_match_op_counts() {
    let (m, report) = traced_run("fft4096r8", MemoryArchKind::banked(8));
    let trace = m.mem_trace();
    let total_ops: u64 = trace.iter().map(|t| t.ops.len() as u64).sum();
    assert_eq!(
        total_ops,
        report.stats.d_load_ops + report.stats.tw_load_ops + report.stats.store_ops
    );
}

#[test]
fn trace_disabled_by_default() {
    let r = BenchJob::new("transpose32", MemoryArchKind::banked(16)).run().unwrap();
    // BenchJob does not enable tracing; nothing to assert on it directly,
    // but a fresh machine without the flag must keep the trace empty.
    let mut m = Machine::new(
        MachineConfig::for_arch(MemoryArchKind::banked(16)).with_mem_words(4096),
    );
    let p = soft_simt::isa::asm::assemble(".threads 16\ntid r0\nld r1, [r0]\nhalt\n").unwrap();
    m.run_program(&p).unwrap();
    assert!(m.mem_trace().is_empty());
    let _ = r;
}
