//! Integration: the analytical timing mode (L1 Pallas conflict kernel via
//! PJRT) must reproduce the cycle-accurate simulator's attributed memory
//! cycles exactly — same conflict maths, same §III-A overhead model.
//!
//! Since the execution/timing split the oracle consumes the same
//! [`soft_simt::sim::exec::MemTrace`] the decoupled simulator replays,
//! and every facade run captures it — no opt-in tracing flag.

use soft_simt::coordinator::job::BenchJob;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::programs::library::program_by_name;
use soft_simt::runtime::analytical::{estimate_banked, estimate_multiport};
use soft_simt::runtime::ArtifactRuntime;
use soft_simt::sim::config::MachineConfig;
use soft_simt::sim::machine::Machine;

fn traced_run(
    program: &str,
    arch: MemoryArchKind,
) -> (Machine, soft_simt::sim::stats::RunReport) {
    let workload = program_by_name(program).unwrap();
    let mut cfg = MachineConfig::for_arch(arch)
        .with_mem_words(workload.mem_words())
        .with_fast_timing();
    if let Some(region) = workload.tw_region() {
        cfg = cfg.with_tw_region(region);
    }
    let mut m = Machine::new(cfg);
    workload.load_input(&mut m, 0x5EED);
    let r = m.run_program(workload.program()).unwrap();
    (m, r)
}

#[test]
fn analytical_banked_equals_simulator() {
    let rt = ArtifactRuntime::from_env().unwrap();
    if !rt.has_artifact("conflict16") {
        eprintln!("skipping: artifacts not built (or the `pjrt` feature is off)");
        return;
    }
    for program in ["transpose32", "fft4096r16"] {
        for arch in [
            MemoryArchKind::banked(16),
            MemoryArchKind::banked_offset(16),
            MemoryArchKind::banked(4),
            MemoryArchKind::banked_offset(8),
        ] {
            let (m, report) = traced_run(program, arch);
            let trace = m.mem_trace().expect("trace captured");
            let est = estimate_banked(&rt, arch, trace).expect("oracle scores trace");
            assert_eq!(
                est.load_cycles,
                report.stats.load_cycles(),
                "{program} on {arch}: loads"
            );
            assert_eq!(
                est.store_cycles, report.stats.store_cycles,
                "{program} on {arch}: stores"
            );
        }
    }
}

#[test]
fn analytical_multiport_equals_simulator() {
    for program in ["transpose64", "fft4096r4"] {
        for arch in [
            MemoryArchKind::mp_4r1w(),
            MemoryArchKind::mp_4r2w(),
            MemoryArchKind::mp_4r1w_vb(),
        ] {
            let (m, report) = traced_run(program, arch);
            let trace = m.mem_trace().expect("trace captured");
            let est = estimate_multiport(arch, trace).unwrap();
            assert_eq!(est.load_cycles, report.stats.load_cycles(), "{program} on {arch}");
            assert_eq!(est.store_cycles, report.stats.store_cycles, "{program} on {arch}");
        }
    }
}

#[test]
fn trace_shapes_match_op_counts() {
    let (m, report) = traced_run("fft4096r8", MemoryArchKind::banked(8));
    let trace = m.mem_trace().expect("trace captured");
    assert_eq!(
        trace.mem_op_count(),
        report.stats.d_load_ops + report.stats.tw_load_ops + report.stats.store_ops
    );
    assert_eq!(trace.segments.len() as u64 + 1, report.stats.instructions - alu_count(trace));
}

/// ALU/other instruction count recorded in a trace (everything except the
/// memory instructions themselves and the final halt).
fn alu_count(trace: &soft_simt::sim::exec::MemTrace) -> u64 {
    trace.segments.iter().map(|s| s.before.instructions).sum::<u64>() + trace.tail.instructions
}

#[test]
fn trace_always_captured() {
    // The decoupled core emits the complete trace on every run — the old
    // `collect_mem_trace` opt-in is gone.
    let r = BenchJob::new("transpose32", MemoryArchKind::banked(16)).run().unwrap();
    let mut m = Machine::new(
        MachineConfig::for_arch(MemoryArchKind::banked(16)).with_mem_words(4096),
    );
    assert!(m.mem_trace().is_none(), "no trace before the first run");
    let p = soft_simt::isa::asm::assemble(".threads 16\ntid r0\nld r1, [r0]\nhalt\n").unwrap();
    m.run_program(&p).unwrap();
    let trace = m.mem_trace().expect("trace captured without any flag");
    assert_eq!(trace.mem_op_count(), 1);
    let _ = r;
}
