//! Integration: assembler/disassembler round-trips over randomly
//! generated valid programs, plus the typed ISA error surface (PR 3)
//! pinned on *mutated* inputs (ISSUE 4 satellite) — a corrupted
//! mnemonic must surface the `UnknownMnemonic` lineage with line
//! context, and a corrupted binary word the typed `DecodeError` with its
//! pc, both folding into `SimError`/`ServiceError` without ever
//! degrading to a bare string at the boundary.

use soft_simt::isa::asm::{assemble, disassemble};
use soft_simt::isa::inst::Instruction;
use soft_simt::isa::opcode::{Opcode, UnknownMnemonic};
use soft_simt::isa::program::Program;
use soft_simt::sim::exec::SimError;
use soft_simt::util::proptest::check;
use soft_simt::util::XorShift64;

/// Generate a random valid program in *canonical operand form* (fields
/// an instruction's assembler syntax does not carry stay zero — exactly
/// what the assembler itself would emit), so text round-trips are exact.
fn random_valid_program(rng: &mut XorShift64, max_len: usize) -> Program {
    let n = 1 + rng.below(max_len as u32) as usize;
    let mut insts = Vec::with_capacity(n);
    for _ in 0..n {
        let op = Opcode::ALL[rng.below(Opcode::ALL.len() as u32) as usize];
        let r = |rng: &mut XorShift64| rng.below(64) as u8;
        let inst = match op {
            Opcode::Nop | Opcode::Halt => Instruction::z(op),
            Opcode::Tid => Instruction::i(op, r(rng), 0, 0),
            Opcode::Jmp => Instruction::i(op, 0, 0, rng.below(n as u32) as u16),
            Opcode::Bnz => Instruction::i(op, r(rng), 0, rng.below(n as u32) as u16),
            Opcode::Ldi | Opcode::Lui => Instruction::i(op, r(rng), 0, rng.next_u32() as u16),
            Opcode::Fneg | Opcode::Itof => Instruction::r(op, r(rng), r(rng), 0),
            Opcode::Ld => Instruction::i(op, r(rng), r(rng), 0),
            Opcode::St | Opcode::Stnb => Instruction::r(op, 0, r(rng), r(rng)),
            _ if Instruction::is_i_format(op) => {
                Instruction::i(op, r(rng), r(rng), rng.next_u32() as u16)
            }
            _ => Instruction::r(op, r(rng), r(rng), r(rng)),
        };
        insts.push(inst);
    }
    Program::new("roundtrip-fuzz", 1 + rng.below(4096), insts)
}

#[test]
fn asm_disasm_asm_roundtrip_property() {
    check("asm → disasm → asm is the identity", 300, |rng| {
        let p = random_valid_program(rng, 60);
        let text = disassemble(&p);
        let q = assemble(&text).expect("disassembly must re-assemble");
        assert_eq!(p.insts, q.insts, "instruction streams diverged:\n{text}");
        assert_eq!(p.threads, q.threads);
        // Idempotence: a second trip emits identical text.
        assert_eq!(disassemble(&q), text);
        // And the binary encoding round-trips through the typed decoder.
        let bin = Program::decode("bin", p.threads, &p.encode()).expect("encode/decode");
        assert_eq!(bin.insts, p.insts);
    });
}

#[test]
fn mutated_mnemonic_pins_typed_unknown_mnemonic_error() {
    check("corrupt mnemonic → UnknownMnemonic with line context", 100, |rng| {
        let p = random_valid_program(rng, 20);
        let text = disassemble(&p);
        // Disassembly layout: ".name", ".threads", blank, then one
        // instruction per line — instruction `i` sits on line 4 + i.
        let pc = rng.below(p.insts.len() as u32) as usize;
        let mutated: Vec<String> = text
            .lines()
            .enumerate()
            .map(|(ln, line)| {
                if ln == 3 + pc {
                    // Replace the mnemonic, keep the operands.
                    let rest = line.trim_start().split_once(' ').map(|(_, r)| r).unwrap_or("");
                    format!("    frobnicate {rest}")
                } else {
                    line.to_string()
                }
            })
            .collect();
        let err = assemble(&(mutated.join("\n") + "\n"))
            .expect_err("unknown mnemonic must not assemble");
        assert_eq!(err.line, 4 + pc, "error must carry the mutated line");
        // The message is the typed UnknownMnemonic's Display, verbatim.
        let typed: UnknownMnemonic = "frobnicate".parse::<Opcode>().unwrap_err();
        assert_eq!(err.msg, typed.to_string());
        assert!(err.to_string().contains("unknown mnemonic 'frobnicate'"));
    });
}

#[test]
fn mutated_binary_word_pins_typed_decode_error() {
    check("corrupt binary word → DecodeError at its pc", 100, |rng| {
        let p = random_valid_program(rng, 20);
        let mut words = p.encode();
        let pc = rng.below(words.len() as u32) as usize;
        // An invalid opcode field (63) is rejected; so are stray high bits.
        words[pc] = if rng.chance(0.5) { 63u64 << 34 } else { (1u64 << 40) | words[pc] };
        let err = Program::decode("bad", p.threads, &words)
            .expect_err("corrupt word must not decode");
        assert_eq!(err.pc, pc, "error must carry the corrupted pc");
        assert_eq!(err.word, words[pc]);
        // The lineage folds into the simulator's error type.
        let sim: SimError = err.into();
        assert!(
            matches!(&sim, SimError::BadProgram(m) if m.contains(&format!("pc {pc}"))),
            "{sim:?}"
        );
    });
}

#[test]
fn roundtrip_survives_simulation_semantics() {
    // Behavioural anchor: a round-tripped memory-safe program simulates
    // identically (complements the structural equality above; uses a
    // small fixed program so every opcode class is exercised without a
    // fuzz-side memory-safety harness).
    use soft_simt::mem::arch::MemoryArchKind;
    use soft_simt::sim::config::MachineConfig;
    use soft_simt::sim::machine::Machine;

    let src = "
.name roundtrip
.threads 48
    tid   r0
    imuli r1, r0, 5
    iandi r1, r1, 1023
    ld    r2, [r1]
    fadd  r3, r2, r2
    st    [r1], r3
    stnb  [r1], r2
    halt
";
    let p = assemble(src).unwrap();
    let q = assemble(&disassemble(&p)).unwrap();
    for program in [&p, &q] {
        let mut m = Machine::new(
            MachineConfig::for_arch(MemoryArchKind::banked_offset(8)).with_mem_words(4096),
        );
        let r = m.run_program(program).unwrap();
        assert!(r.total_cycles() > 0);
    }
    let mut ma = Machine::new(
        MachineConfig::for_arch(MemoryArchKind::banked_offset(8)).with_mem_words(4096),
    );
    let mut mb = Machine::new(
        MachineConfig::for_arch(MemoryArchKind::banked_offset(8)).with_mem_words(4096),
    );
    let ra = ma.run_program(&p).unwrap();
    let rb = mb.run_program(&q).unwrap();
    assert_eq!(ra.stats, rb.stats);
    assert_eq!(ra.total_cycles(), rb.total_cycles());
    assert_eq!(ma.mem().image(), mb.mem().image());
}
