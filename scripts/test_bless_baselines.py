"""Unit tests for bless_baselines.py (run by the CI python step:
`python3 -m unittest discover -s scripts -p 'test_*.py'`).

Pins the two behaviors bless.yml's decide job keys off: the fold path
copies exactly the gated metrics into a baseline (preserving its note),
and --check-null reports every null gated metric with an end summary,
exiting 0 while a bless is still needed and 1 once everything is
blessed.
"""

import io
import json
import sys
import tempfile
import unittest
from contextlib import redirect_stdout
from pathlib import Path
from unittest import mock

sys.path.insert(0, str(Path(__file__).resolve().parent))
import bless_baselines  # noqa: E402


class BlessHarness(unittest.TestCase):
    def setUp(self):
        self.tmp = tempfile.TemporaryDirectory()
        self.addCleanup(self.tmp.cleanup)
        self.dir = Path(self.tmp.name)

    def write(self, name, payload):
        path = self.dir / name
        path.write_text(json.dumps(payload))
        return str(path)

    def patched_plan(self, plan):
        return mock.patch.object(bless_baselines, "PLAN", plan)


class CheckNullTests(BlessHarness):
    def test_null_metrics_are_listed_and_summarized(self):
        base_a = self.write("a.json", {"m1": None, "m2": 3.0})
        base_b = self.write("b.json", {"m3": None})
        plan = [([], base_a, ["m1", "m2"]), ([], base_b, ["m3"])]
        out = io.StringIO()
        with self.patched_plan(plan), redirect_stdout(out):
            code = bless_baselines.check_null()
        self.assertEqual(code, 0, "exit 0 = a bless is still needed")
        text = out.getvalue()
        self.assertIn(f"unblessed: {base_a}: m1", text)
        self.assertIn(f"unblessed: {base_b}: m3", text)
        self.assertNotIn(f"unblessed: {base_a}: m2", text, "blessed metrics are not listed")
        self.assertIn("summary: 2 gated metric(s) unblessed across 2 baseline file(s)", text)

    def test_absent_metric_counts_as_unblessed(self):
        base = self.write("a.json", {"other": 1.0})
        out = io.StringIO()
        with self.patched_plan([([], base, ["m1"])]), redirect_stdout(out):
            code = bless_baselines.check_null()
        self.assertEqual(code, 0)
        self.assertIn("summary: 1 gated metric(s) unblessed across 1 baseline file(s)",
                      out.getvalue())

    def test_fully_blessed_exits_one_with_no_summary(self):
        base = self.write("a.json", {"m1": 1.0, "m2": 2.0})
        out = io.StringIO()
        with self.patched_plan([([], base, ["m1", "m2"])]), redirect_stdout(out):
            code = bless_baselines.check_null()
        self.assertEqual(code, 1, "exit 1 = nothing left to bless")
        self.assertIn("all gated baseline metrics already blessed", out.getvalue())
        self.assertNotIn("summary:", out.getvalue())

    def test_missing_baseline_file_is_io_error(self):
        with self.patched_plan([([], str(self.dir / "gone.json"), ["m1"])]):
            code = bless_baselines.check_null()
        self.assertEqual(code, 2)


class FoldTests(BlessHarness):
    def test_fold_copies_gated_metrics_and_preserves_note(self):
        cur = self.write("fresh.json", {"m1": 4.5, "m2": 6.0, "untracked": 9.9})
        base = self.write("base.json", {"note": "keep me", "m1": None, "m2": None})
        with self.patched_plan([([cur], base, ["m1", "m2"])]), \
                mock.patch.object(sys, "argv", ["bless_baselines.py"]), \
                redirect_stdout(io.StringIO()):
            code = bless_baselines.main()
        self.assertEqual(code, 0)
        blessed = json.loads(Path(base).read_text())
        self.assertEqual(blessed["m1"], 4.5)
        self.assertEqual(blessed["m2"], 6.0)
        self.assertEqual(blessed["note"], "keep me")
        self.assertNotIn("untracked", blessed, "a baseline is a contract, not a log")

    def test_fold_fails_when_fresh_json_lacks_a_gated_metric(self):
        cur = self.write("fresh.json", {"m1": 4.5})
        base = self.write("base.json", {"m1": None, "m2": None})
        with self.patched_plan([([cur], base, ["m1", "m2"])]), \
                mock.patch.object(sys, "argv", ["bless_baselines.py"]), \
                redirect_stdout(io.StringIO()):
            code = bless_baselines.main()
        self.assertEqual(code, 2)

    def test_serve_plan_gates_the_saturation_keys(self):
        # The real PLAN must gate every saturation metric the serve
        # bench emits — drift here silently un-gates the new keys.
        serve = next(e for e in bless_baselines.PLAN
                     if e[1].endswith("BENCH_serve.json"))
        for c in (1, 4, 16):
            for suffix in ("p50_us", "p99_us", "throughput_rps"):
                self.assertIn(f"concurrent_c{c}_{suffix}", serve[2])

    def test_explore_plan_gates_the_system_explore_key(self):
        # Drift guard for the ISSUE-10 system-explore median: the gate
        # and the bless plan must stay in sync on the new key.
        explore = next(e for e in bless_baselines.PLAN
                       if e[1].endswith("BENCH_explore.json"))
        self.assertIn("system_explore_median_ms", explore[2])

    def test_sweep_plan_gates_the_divergent_kernel_keys(self):
        # Same drift guard for the PR-9 divergent-kernel replay medians.
        sweep = next(e for e in bless_baselines.PLAN
                     if e[1].endswith("BENCH_sweep.json"))
        for key in ("bitonic_replay_median_ms", "spmv_replay_median_ms"):
            self.assertIn(key, sweep[2])


if __name__ == "__main__":
    unittest.main()
