"""Unit tests for check_bench_regression.py (run by the CI python step:
`python3 -m unittest discover -s scripts -p 'test_*.py'`).

The gate has three modes — baseline-relative regression budgets
(--metrics/--max-regression), absolute higher-is-better floors
(--floor), and absolute lower-is-better ceilings (--ceiling, bounding
the observability overhead) — plus the null-baseline skip path. Each is
pinned here by invoking main() in-process with patched argv.
"""

import json
import sys
import tempfile
import unittest
from pathlib import Path
from unittest import mock

sys.path.insert(0, str(Path(__file__).resolve().parent))
import check_bench_regression  # noqa: E402


class GateHarness(unittest.TestCase):
    def setUp(self):
        self.tmp = tempfile.TemporaryDirectory()
        self.addCleanup(self.tmp.cleanup)
        self.dir = Path(self.tmp.name)

    def write(self, name, payload):
        path = self.dir / name
        path.write_text(json.dumps(payload))
        return str(path)

    def run_gate(self, *argv):
        with mock.patch.object(sys, "argv", ["check_bench_regression.py", *argv]):
            return check_bench_regression.main()


class MetricsModeTests(GateHarness):
    def test_within_budget_passes(self):
        cur = self.write("cur.json", {"warm_median_ms": 11.0})
        base = self.write("base.json", {"warm_median_ms": 10.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--metrics", "warm_median_ms", "--max-regression", "1.20")
        self.assertEqual(code, 0)

    def test_regression_past_budget_fails(self):
        cur = self.write("cur.json", {"warm_median_ms": 12.5})
        base = self.write("base.json", {"warm_median_ms": 10.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--metrics", "warm_median_ms", "--max-regression", "1.20")
        self.assertEqual(code, 1)

    def test_null_baseline_is_skipped_not_failed(self):
        cur = self.write("cur.json", {"warm_median_ms": 999.0})
        base = self.write("base.json", {"warm_median_ms": None})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--metrics", "warm_median_ms")
        self.assertEqual(code, 0, "null baseline means 'not blessed yet', never a failure")

    def test_metric_missing_from_current_fails(self):
        cur = self.write("cur.json", {})
        base = self.write("base.json", {"warm_median_ms": 10.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--metrics", "warm_median_ms")
        self.assertEqual(code, 1)

    def test_first_existing_current_candidate_wins(self):
        cur = self.write("cur.json", {"warm_median_ms": 10.0})
        base = self.write("base.json", {"warm_median_ms": 10.0})
        missing = str(self.dir / "does_not_exist.json")
        code = self.run_gate("--current", missing, cur, "--baseline", base,
                             "--metrics", "warm_median_ms")
        self.assertEqual(code, 0)

    def test_no_current_anywhere_is_usage_error(self):
        base = self.write("base.json", {"warm_median_ms": 10.0})
        code = self.run_gate("--current", str(self.dir / "nope.json"),
                             "--baseline", base, "--metrics", "warm_median_ms")
        self.assertEqual(code, 2)


class ThroughputModeTests(GateHarness):
    """--min-throughput-metrics: baseline-relative, higher is better."""

    def test_throughput_within_budget_passes(self):
        cur = self.write("cur.json", {"concurrent_c16_throughput_rps": 900.0})
        base = self.write("base.json", {"concurrent_c16_throughput_rps": 1000.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--min-throughput-metrics", "concurrent_c16_throughput_rps",
                             "--max-regression", "1.20")
        self.assertEqual(code, 0, "900 >= 1000/1.20 is inside the budget")

    def test_throughput_collapse_fails(self):
        cur = self.write("cur.json", {"concurrent_c16_throughput_rps": 700.0})
        base = self.write("base.json", {"concurrent_c16_throughput_rps": 1000.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--min-throughput-metrics", "concurrent_c16_throughput_rps",
                             "--max-regression", "1.20")
        self.assertEqual(code, 1, "700 < 1000/1.20 busts the budget")

    def test_null_throughput_baseline_is_skipped(self):
        cur = self.write("cur.json", {"concurrent_c16_throughput_rps": 1.0})
        base = self.write("base.json", {"concurrent_c16_throughput_rps": None})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--min-throughput-metrics", "concurrent_c16_throughput_rps")
        self.assertEqual(code, 0, "null baseline means 'not blessed yet', never a failure")

    def test_throughput_missing_from_current_fails(self):
        cur = self.write("cur.json", {})
        base = self.write("base.json", {"concurrent_c16_throughput_rps": 1000.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--min-throughput-metrics", "concurrent_c16_throughput_rps")
        self.assertEqual(code, 1)

    def test_improved_throughput_passes(self):
        cur = self.write("cur.json", {"concurrent_c16_throughput_rps": 2000.0})
        base = self.write("base.json", {"concurrent_c16_throughput_rps": 1000.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--min-throughput-metrics", "concurrent_c16_throughput_rps")
        self.assertEqual(code, 0)


class FloorModeTests(GateHarness):
    def test_floor_met_passes(self):
        cur = self.write("cur.json", {"simd_speedup": 5.1})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--floor", "simd_speedup=4.0")
        self.assertEqual(code, 0)

    def test_floor_violated_fails(self):
        cur = self.write("cur.json", {"simd_speedup": 3.2})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--floor", "simd_speedup=4.0")
        self.assertEqual(code, 1)

    def test_bad_floor_spec_is_usage_error(self):
        cur = self.write("cur.json", {"simd_speedup": 5.0})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--floor", "simd_speedup")
        self.assertEqual(code, 2)


class CeilingModeTests(GateHarness):
    def test_under_ceiling_passes(self):
        cur = self.write("cur.json", {"instrumented_overhead_pct": 0.7})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--ceiling", "instrumented_overhead_pct=2.0")
        self.assertEqual(code, 0)

    def test_at_ceiling_passes(self):
        cur = self.write("cur.json", {"instrumented_overhead_pct": 2.0})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--ceiling", "instrumented_overhead_pct=2.0")
        self.assertEqual(code, 0, "the ceiling itself is inside the budget")

    def test_over_ceiling_fails(self):
        cur = self.write("cur.json", {"instrumented_overhead_pct": 2.3})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--ceiling", "instrumented_overhead_pct=2.0")
        self.assertEqual(code, 1)

    def test_missing_ceiling_metric_fails(self):
        # A bench that stops emitting the overhead number must not
        # silently pass the overhead gate.
        cur = self.write("cur.json", {"warm_median_ms": 1.0})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--ceiling", "instrumented_overhead_pct=2.0")
        self.assertEqual(code, 1)

    def test_bad_ceiling_spec_is_usage_error(self):
        cur = self.write("cur.json", {"instrumented_overhead_pct": 1.0})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--ceiling", "overhead=not_a_number")
        self.assertEqual(code, 2)


class CombinedModeTests(GateHarness):
    def test_nothing_to_check_is_usage_error(self):
        cur = self.write("cur.json", {})
        base = self.write("base.json", {})
        code = self.run_gate("--current", cur, "--baseline", base)
        self.assertEqual(code, 2)

    def test_any_failing_mode_fails_the_gate(self):
        cur = self.write("cur.json", {
            "warm_median_ms": 10.0,
            "simd_speedup": 5.0,
            "instrumented_overhead_pct": 9.9,
        })
        base = self.write("base.json", {"warm_median_ms": 10.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--metrics", "warm_median_ms",
                             "--floor", "simd_speedup=4.0",
                             "--ceiling", "instrumented_overhead_pct=2.0")
        self.assertEqual(code, 1)

    def test_all_modes_passing_together(self):
        cur = self.write("cur.json", {
            "warm_median_ms": 10.5,
            "simd_speedup": 5.0,
            "instrumented_overhead_pct": 0.4,
        })
        base = self.write("base.json", {"warm_median_ms": 10.0})
        code = self.run_gate("--current", cur, "--baseline", base,
                             "--metrics", "warm_median_ms",
                             "--floor", "simd_speedup=4.0",
                             "--ceiling", "instrumented_overhead_pct=2.0")
        self.assertEqual(code, 0)


if __name__ == "__main__":
    unittest.main()
