#!/usr/bin/env python3
"""Fail CI when a bench JSON regresses past a tolerance vs a committed baseline.

Usage:
  check_bench_regression.py --current CAND [CAND ...] --baseline BASE \
      --metrics NAME [NAME ...] [--max-regression 1.20] \
      [--min-throughput-metrics NAME [NAME ...]] \
      [--floor NAME=VALUE [NAME=VALUE ...]] \
      [--ceiling NAME=VALUE [NAME=VALUE ...]]

- CAND: candidate locations of the freshly produced bench JSON (the first
  existing path wins; cargo runs bench binaries from the package root, so
  the file may land in ./ or rust/).
- BASE: the committed baseline JSON. A metric whose baseline value is
  null (or absent) is skipped with a notice — that is the "no trusted
  measurement recorded yet" state. Bless a baseline from the bench-json
  artifact of a trusted CI run on the same runner class (absolute
  wall-clock medians only compare meaningfully on like hardware; a
  workstation-blessed number makes the budget fire spuriously or never).
- Metrics are medians in milliseconds: lower is better, and the gate
  fails when current > baseline * max_regression (default 1.20 = the
  >20% regression budget of ISSUE 4).
- --min-throughput-metrics is the baseline-relative higher-is-better
  twin (requests/sec from the serve saturation bench): the gate fails
  when current < baseline / max_regression, and a null/absent baseline
  is skipped with the same bless notice.
- Floors are higher-is-better ABSOLUTE gates, independent of the
  baseline file: `--floor simd_speedup=4.0` fails when the current
  JSON's `simd_speedup` is below 4.0 or missing. Use floors for
  dimensionless ratios (speedups) that do not depend on runner speed
  and therefore need no per-runner blessing.
- Ceilings are the lower-is-better twin of floors (absolute, no
  baseline): `--ceiling instrumented_overhead_pct=2.0` fails when the
  current JSON's `instrumented_overhead_pct` exceeds 2.0 or is missing.
  Used to bound the observability overhead on the replay hot path
  (DESIGN.md §Observability).

Exit codes: 0 ok/skipped, 1 regression, 2 usage/IO error.
"""

import argparse
import json
import sys
from pathlib import Path


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", nargs="+", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--metrics", nargs="*", default=[])
    ap.add_argument("--max-regression", type=float, default=1.20)
    ap.add_argument("--min-throughput-metrics", nargs="*", default=[])
    ap.add_argument("--floor", nargs="*", default=[], metavar="NAME=VALUE")
    ap.add_argument("--ceiling", nargs="*", default=[], metavar="NAME=VALUE")
    args = ap.parse_args()
    if (not args.metrics and not args.min_throughput_metrics
            and not args.floor and not args.ceiling):
        print("error: nothing to check (need --metrics, --min-throughput-metrics, "
              "--floor and/or --ceiling)", file=sys.stderr)
        return 2

    def parse_thresholds(specs, flag):
        parsed = []
        for spec in specs:
            name, sep, value = spec.partition("=")
            try:
                threshold = float(value)
            except ValueError:
                sep = ""
            if not sep or not name:
                print(f"error: bad {flag} spec {spec!r} (want NAME=VALUE)", file=sys.stderr)
                return None
            parsed.append((name, threshold))
        return parsed

    floors = parse_thresholds(args.floor, "--floor")
    ceilings = parse_thresholds(args.ceiling, "--ceiling")
    if floors is None or ceilings is None:
        return 2

    current_path = next((p for p in map(Path, args.current) if p.is_file()), None)
    if current_path is None:
        print(f"error: no current bench JSON found among {args.current}", file=sys.stderr)
        return 2
    baseline_path = Path(args.baseline)
    if not baseline_path.is_file():
        print(f"error: baseline {baseline_path} missing", file=sys.stderr)
        return 2

    try:
        current = json.loads(current_path.read_text())
        baseline = json.loads(baseline_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    failed = False
    for metric in args.metrics:
        base = baseline.get(metric)
        cur = current.get(metric)
        if base is None:
            print(f"skip  {metric}: no committed baseline yet (null/absent) — "
                  f"bless {baseline_path} from the bench-json artifact of a trusted CI run")
            continue
        if cur is None:
            print(f"FAIL  {metric}: missing from {current_path}", file=sys.stderr)
            failed = True
            continue
        budget = base * args.max_regression
        verdict = "FAIL" if cur > budget else "ok"
        line = (f"{verdict:5} {metric}: current {cur:.3f} vs baseline {base:.3f} "
                f"(budget {budget:.3f}, x{args.max_regression:.2f})")
        if cur > budget:
            print(line, file=sys.stderr)
            failed = True
        else:
            print(line)
    for metric in args.min_throughput_metrics:
        base = baseline.get(metric)
        cur = current.get(metric)
        if base is None:
            print(f"skip  {metric}: no committed baseline yet (null/absent) — "
                  f"bless {baseline_path} from the bench-json artifact of a trusted CI run")
            continue
        if cur is None:
            print(f"FAIL  {metric}: missing from {current_path}", file=sys.stderr)
            failed = True
            continue
        budget = base / args.max_regression
        verdict = "FAIL" if cur < budget else "ok"
        line = (f"{verdict:5} {metric}: current {cur:.3f} vs baseline {base:.3f} "
                f"(budget {budget:.3f}, /{args.max_regression:.2f}, higher is better)")
        if cur < budget:
            print(line, file=sys.stderr)
            failed = True
        else:
            print(line)
    for name, floor in floors:
        cur = current.get(name)
        if cur is None:
            print(f"FAIL  {name}: missing from {current_path} (floor {floor:.3f})",
                  file=sys.stderr)
            failed = True
            continue
        verdict = "FAIL" if cur < floor else "ok"
        line = f"{verdict:5} {name}: current {cur:.3f} vs floor {floor:.3f} (higher is better)"
        if cur < floor:
            print(line, file=sys.stderr)
            failed = True
        else:
            print(line)
    for name, ceiling in ceilings:
        cur = current.get(name)
        if cur is None:
            print(f"FAIL  {name}: missing from {current_path} (ceiling {ceiling:.3f})",
                  file=sys.stderr)
            failed = True
            continue
        verdict = "FAIL" if cur > ceiling else "ok"
        line = f"{verdict:5} {name}: current {cur:.3f} vs ceiling {ceiling:.3f} (lower is better)"
        if cur > ceiling:
            print(line, file=sys.stderr)
            failed = True
        else:
            print(line)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
