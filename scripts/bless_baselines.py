#!/usr/bin/env python3
"""Fold freshly measured bench medians into the committed baselines.

Companion to check_bench_regression.py and the bless.yml workflow: after
`cargo bench` writes BENCH_explore.json / BENCH_sweep.json /
BENCH_serve.json, this copies exactly the GATED metrics into the matching
rust/benches/baselines/BENCH_*.json, preserving each baseline's note.
Metrics the gate does not read are left out of the baseline on purpose —
a baseline is a contract, not a log.

Run on the CI runner class only (see the note inside each baseline).

`--check-null` does not bless: it inspects only the committed baselines
and exits 0 when some gated metric is still null/absent (a bless is
needed — e.g. a PR just added a new gated metric) and 1 when every
gated metric already has a trusted measurement. bless.yml uses this to
self-trigger exactly once after CI lands a new metric.

Exit codes: 0 ok / bless needed, 1 (--check-null) nothing to bless,
2 missing/invalid inputs.
"""

import json
import sys
from pathlib import Path

# (fresh candidates, committed baseline, gated metrics) — keep in sync
# with the bench gates in .github/workflows/ci.yml.
PLAN = [
    (
        ["BENCH_explore.json", "rust/BENCH_explore.json"],
        "rust/benches/baselines/BENCH_explore.json",
        [
            "exhaustive_median_ms",
            "halving_median_ms",
            "replay_batched_archset_ms",
            "replay_packed_archset_ms",
            "system_explore_median_ms",
        ],
    ),
    (
        ["BENCH_sweep.json", "rust/BENCH_sweep.json"],
        "rust/benches/baselines/BENCH_sweep.json",
        [
            "trace_cached_median_ms",
            "replay_batched_median_ms",
            "replay_packed_median_ms",
            "bitonic_replay_median_ms",
            "spmv_replay_median_ms",
        ],
    ),
    (
        ["BENCH_serve.json", "rust/BENCH_serve.json"],
        "rust/benches/baselines/BENCH_serve.json",
        [
            "cold_median_ms",
            "warm_median_ms",
            "concurrent_c1_p50_us",
            "concurrent_c1_p99_us",
            "concurrent_c1_throughput_rps",
            "concurrent_c4_p50_us",
            "concurrent_c4_p99_us",
            "concurrent_c4_throughput_rps",
            "concurrent_c16_p50_us",
            "concurrent_c16_p99_us",
            "concurrent_c16_throughput_rps",
        ],
    ),
]


def check_null() -> int:
    unblessed = 0
    files_with_nulls = set()
    for _, baseline, metrics in PLAN:
        baseline_path = Path(baseline)
        if not baseline_path.is_file():
            print(f"error: baseline {baseline} missing from the checkout", file=sys.stderr)
            return 2
        try:
            base = json.loads(baseline_path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        for metric in metrics:
            if base.get(metric) is None:
                print(f"unblessed: {baseline}: {metric}")
                unblessed += 1
                files_with_nulls.add(baseline)
    if unblessed:
        print(f"summary: {unblessed} gated metric(s) unblessed across "
              f"{len(files_with_nulls)} baseline file(s)")
    else:
        print("all gated baseline metrics already blessed")
    return 0 if unblessed else 1


def main() -> int:
    if "--check-null" in sys.argv[1:]:
        return check_null()
    for candidates, baseline, metrics in PLAN:
        current_path = next((p for p in map(Path, candidates) if p.is_file()), None)
        if current_path is None:
            print(f"error: no fresh bench JSON among {candidates}", file=sys.stderr)
            return 2
        baseline_path = Path(baseline)
        if not baseline_path.is_file():
            print(f"error: baseline {baseline} missing from the checkout", file=sys.stderr)
            return 2
        try:
            current = json.loads(current_path.read_text())
            base = json.loads(baseline_path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        for metric in metrics:
            value = current.get(metric)
            if value is None:
                print(f"error: {metric} missing from {current_path}", file=sys.stderr)
                return 2
            print(f"bless {baseline}: {metric} = {value}")
            base[metric] = value
        baseline_path.write_text(json.dumps(base, indent=2) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
