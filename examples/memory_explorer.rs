//! Memory-architecture explorer: sweep a custom access pattern across all
//! nine shared memories — the "informed memory architecture decision"
//! workflow the paper's abstract promises, for *your* kernel instead of
//! the paper's.
//!
//! ```sh
//! cargo run --release --example memory_explorer -- [stride] [threads]
//! ```

use soft_simt::area::footprint;
use soft_simt::isa::asm::assemble;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::sim::config::MachineConfig;
use soft_simt::sim::machine::Machine;

/// A strided read-modify-write kernel: the access pattern knob that moves
/// a workload between the multiport and banked sweet spots.
fn strided_kernel(stride: u32, threads: u32, words: u32) -> String {
    format!(
        "
.name strided{stride}
.threads {threads}
    tid   r0
    imuli r1, r0, {stride}
    iandi r1, r1, {mask}      ; wrap into the address space
    ld    r2, [r1]
    iaddi r2, r2, 1
    st    [r1], r2
    halt
",
        mask = words - 1,
    )
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let stride: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(16);
    let threads: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let words: u32 = 16_384;

    let src = strided_kernel(stride, threads, words);
    let program = assemble(&src).expect("kernel assembles");
    println!("exploring stride-{stride} RMW over {threads} threads ({} B dataset)\n", words * 4);
    println!(
        "{:<18} {:>9} {:>9} {:>9} {:>10} {:>12}",
        "memory", "cycles", "time(us)", "R-eff(%)", "W-eff(%)", "mem ALMs@64K"
    );

    let mut rows: Vec<(String, f64)> = Vec::new();
    for arch in MemoryArchKind::table3_nine() {
        let mut machine = Machine::new(
            MachineConfig::for_arch(arch)
                .with_mem_words(words as usize)
                .with_fast_timing(),
        );
        let report = machine.run_program(&program).expect("runs");
        let alms = footprint::memory_alms(arch, 64)
            .map(|a| a.to_string())
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<18} {:>9} {:>9.2} {:>9} {:>10} {:>12}",
            arch.label(),
            report.total_cycles(),
            report.time_us(),
            report
                .r_bank_eff()
                .map(|e| format!("{:.1}", e * 100.0))
                .unwrap_or_else(|| "-".into()),
            report
                .w_bank_eff()
                .map(|e| format!("{:.1}", e * 100.0))
                .unwrap_or_else(|| "-".into()),
            alms,
        );
        rows.push((arch.label(), report.time_us()));
    }

    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\nranking for this pattern:");
    for (i, (label, t)) in rows.iter().enumerate() {
        println!("  {}. {label} ({t:.2} us)", i + 1);
    }
    println!(
        "\ntry `-- 1 1024` (conflict-free) vs `-- 16 1024` (worst case) vs \
         `-- 4 1024` (Offset map's sweet spot)"
    );
}
