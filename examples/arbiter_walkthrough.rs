//! Walk through the paper's Figures 4 and 6: the bank-mapping example and
//! the carry-chain arbiter trace, printed step by step.
//!
//! ```sh
//! cargo run --release --example arbiter_walkthrough
//! ```

use soft_simt::mem::arbiter::{BankArbiters, CarryChainArbiter};
use soft_simt::mem::conflict::analyze;
use soft_simt::mem::mapping::{BankMap, BankMapping};
use soft_simt::mem::LANES;

fn bits8(v: u16) -> String {
    (0..8).rev().map(|i| if v >> i & 1 == 1 { '1' } else { '0' }).collect()
}

fn main() {
    // Fig. 4: an 8-lane / 8-bank operation. Lanes access banks
    // [0,1,1,3,1,3,4,5]; bank 1 is hit by lanes 1, 2 and 4.
    let map = BankMap::new(8, BankMapping::Lsb);
    let banks_by_lane = [0u32, 1, 1, 3, 1, 3, 4, 5];
    let mut addrs = [0u32; LANES];
    for (lane, &b) in banks_by_lane.iter().enumerate() {
        addrs[lane] = 8 + b;
    }
    let info = analyze(&addrs, 0x00FF, &map);

    println!("Fig. 4 — bank mapping (8 lanes, 8 banks, LSB map)");
    println!("lane -> bank: {banks_by_lane:?}");
    println!("\none-hot bank matrix columns (bit l = lane l accesses the bank):");
    for (bank, col) in info.columns.iter().enumerate() {
        println!("  bank {bank}: {} (count {})", bits8(*col), info.counts[bank]);
    }
    println!("max conflicts = {} -> the controller spaces the next operation by {} cycles",
             info.max_conflicts, info.max_conflicts);
    assert_eq!(info.max_conflicts, 3);

    // Fig. 6: the carry-chain arbiter for bank 1, cycle by cycle.
    println!("\nFig. 6 — carry-chain arbitrate for bank 1 (vector {})", bits8(info.columns[1]));
    let mut arb = CarryChainArbiter::load(info.columns[1]);
    let mut cycle = 0;
    while !arb.done() {
        let before = arb.pending();
        let grant = arb.step().unwrap();
        cycle += 1;
        println!(
            "  cycle {cycle}: state {} - 1 -> grant {} (lane {}), corrected state {}",
            bits8(before),
            bits8(grant),
            grant.trailing_zeros(),
            bits8(arb.pending()),
        );
    }
    assert_eq!(cycle, 3, "three grants for three requests");

    // The whole Fig. 3 stage: all 8 arbiters in lock step.
    println!("\nfull schedule (bank -> lane per cycle; '.' = idle):");
    let schedule = BankArbiters::load(&info.columns).run();
    for (c, row) in schedule.iter().enumerate() {
        let cells: Vec<String> = row
            .iter()
            .map(|&g| {
                if g == 0 {
                    ".".into()
                } else {
                    format!("{}", g.trailing_zeros())
                }
            })
            .collect();
        println!("  cycle {}: [{}]", c + 1, cells.join(" "));
    }
    println!("\nbank 2 never fires — \"if there is any bank with more than one access,");
    println!("then there must be a bank with zero accesses\" (paper, §III-B)");
}
