//! Write-your-own-assembler demo: a dot-product reduction kernel built
//! with the ProgramBuilder API, run on two memory architectures, with the
//! blocking/non-blocking write trade-off (§III-A) made visible.
//!
//! ```sh
//! cargo run --release --example custom_kernel
//! ```

use soft_simt::isa::asm::disassemble;
use soft_simt::isa::program::Program;
use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::programs::builder::ProgramBuilder;
use soft_simt::sim::config::MachineConfig;
use soft_simt::sim::machine::Machine;
use soft_simt::util::XorShift64;

/// Each thread computes x[i]·y[i] and writes the product to out[i];
/// `blocking` selects `st` vs `stnb` for the result writeback.
fn dot_kernel(n: u32, blocking: bool) -> Program {
    let mut b = ProgramBuilder::new(if blocking { "dot_st" } else { "dot_stnb" }, n);
    let tid = 0u8;
    b.tid(tid);
    let (xa, ya, oa) = (b.alloc(), b.alloc(), b.alloc());
    let (x, y) = (b.alloc(), b.alloc());
    // x at 0, y at n, out at 2n.
    b.iaddi(xa, tid, 0);
    b.iaddi(ya, tid, n as i32);
    b.iaddi(oa, tid, 2 * n as i32);
    b.ld(x, xa);
    b.ld(y, ya);
    b.fmul(x, x, y);
    if blocking {
        b.st(oa, x);
    } else {
        b.stnb(oa, x);
    }
    // Post-store ALU work that can hide behind a non-blocking write.
    for _ in 0..8 {
        b.fadd(y, y, y);
    }
    b.halt();
    b.build()
}

fn main() {
    let n = 4096u32;
    let mut rng = XorShift64::new(77);
    let xs = rng.f32_vec(n as usize);
    let ys = rng.f32_vec(n as usize);

    println!("generated kernel (blocking variant):\n{}", disassemble(&dot_kernel(n, true)));

    for arch in [MemoryArchKind::mp_4r1w(), MemoryArchKind::banked_offset(16)] {
        for blocking in [true, false] {
            let program = dot_kernel(n, blocking);
            let mut m =
                Machine::new(MachineConfig::for_arch(arch).with_mem_words(16_384));
            m.load_f32_image(0, &xs);
            m.load_f32_image(n, &ys);
            let report = m.run_program(&program).expect("runs");
            // Verify the products.
            let out = m.read_f32_image(2 * n, n as usize);
            for i in 0..n as usize {
                assert_eq!(out[i], xs[i] * ys[i], "lane {i}");
            }
            println!(
                "{:<18} {:7}  total {:>6} cycles  (store {:>5}, drain-wait {:>4}) ✓",
                arch.label(),
                if blocking { "st" } else { "stnb" },
                report.total_cycles(),
                report.stats.store_cycles,
                report.stats.drain_cycles,
            );
        }
    }
    println!(
        "\nthe stnb variants hide the 8 trailing FP ops inside the write drain —\n\
         the paper's §III-A blocking/non-blocking distinction at work"
    );
}
