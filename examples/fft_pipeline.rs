//! End-to-end driver: the full three-layer pipeline on a real workload.
//!
//! 1. generates the paper's radix-16 4096-point FFT assembler program,
//! 2. runs it on the cycle-accurate machine for **all nine** memory
//!    architectures (the Table III row set),
//! 3. validates every memory image against the **PJRT-executed golden
//!    FFT** (the L2 JAX model with the L1 Pallas butterfly kernels, AOT-
//!    lowered by `make artifacts`) and the host reference,
//! 4. prints the paper-style profile and declares the winner.
//!
//! This is the repo's proof that L3 (Rust simulator/coordinator), L2 (JAX
//! model) and L1 (Pallas kernels) compose: the same spectrum comes out of
//! all three. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example fft_pipeline
//! ```

use soft_simt::mem::arch::MemoryArchKind;
use soft_simt::programs::fft::{digit_reverse, fft_program, reference_fft};
use soft_simt::runtime::golden::validate_fft;
use soft_simt::runtime::ArtifactRuntime;
use soft_simt::sim::config::MachineConfig;
use soft_simt::sim::machine::Machine;
use soft_simt::util::XorShift64;

fn main() {
    let (plan, program) = fft_program(16);
    println!(
        "radix-16 4096-point FFT: {} instructions, {} threads, {} stages, 64 KB dataset",
        program.insts.len(),
        program.threads,
        plan.stages
    );

    // Input signal: two tones + noise.
    let mut rng = XorShift64::new(2025);
    let n = plan.n as usize;
    let mut re = vec![0.0f32; n];
    let mut im = vec![0.0f32; n];
    for (k, r) in re.iter_mut().enumerate() {
        let t = k as f32 / n as f32;
        *r = (2.0 * std::f32::consts::PI * 13.0 * t).sin()
            + 0.5 * (2.0 * std::f32::consts::PI * 201.0 * t).cos()
            + 0.01 * rng.signed_f32();
    }
    for i in im.iter_mut() {
        *i = 0.01 * rng.signed_f32();
    }
    let mut interleaved = Vec::with_capacity(2 * n);
    for k in 0..n {
        interleaved.push(re[k]);
        interleaved.push(im[k]);
    }

    let rt = ArtifactRuntime::from_env().ok().filter(|rt| rt.has_artifact("fft4096"));
    if rt.is_none() {
        println!("(artifacts not built — golden validation vs host reference only;");
        println!(" run `make artifacts` for the PJRT path)");
    }
    let (hr, hi) = reference_fft(&re, &im);

    println!(
        "\n{:<18} {:>10} {:>9} {:>8} {:>8} {:>10}",
        "memory", "cycles", "time(us)", "eff(%)", "D-eff(%)", "golden"
    );
    let mut best: Option<(String, f64)> = None;
    for arch in MemoryArchKind::table3_nine() {
        let cfg = MachineConfig::for_arch(arch)
            .with_mem_words(plan.mem_words())
            .with_tw_region(plan.tw_region())
            .with_fast_timing();
        let mut machine = Machine::new(cfg);
        machine.load_f32_image(plan.data_base, &interleaved);
        machine.load_f32_image(plan.tw_base, &plan.twiddles);
        let report = machine.run_program(&program).expect("fft runs");

        // Validate: PJRT golden when available, host reference always.
        let golden = match &rt {
            Some(rt) => {
                let rel = validate_fft(rt, &machine, &plan, &re, &im).expect("golden executes");
                assert!(rel < 2e-5, "{arch}: rel err {rel}");
                format!("pjrt {rel:.1e}")
            }
            None => {
                let out = machine.read_f32_image(plan.data_base, 2 * n);
                let mut max_err = 0.0f64;
                let mut max_mag = 1e-30f64;
                for k in 0..n {
                    let p = digit_reverse(k as u32, plan.radix, plan.stages) as usize;
                    let e = ((out[2 * p] as f64 - hr[k]).powi(2)
                        + (out[2 * p + 1] as f64 - hi[k]).powi(2))
                    .sqrt();
                    max_err = max_err.max(e);
                    max_mag = max_mag.max((hr[k].powi(2) + hi[k].powi(2)).sqrt());
                }
                let rel = max_err / max_mag;
                assert!(rel < 2e-5, "{arch}: rel err {rel}");
                format!("host {rel:.1e}")
            }
        };
        let t = report.time_us();
        println!(
            "{:<18} {:>10} {:>9.2} {:>8.1} {:>8} {:>10}",
            arch.label(),
            report.total_cycles(),
            t,
            report.compute_efficiency() * 100.0,
            report
                .r_bank_eff()
                .map(|e| format!("{:.1}", e * 100.0))
                .unwrap_or_else(|| "-".into()),
            golden,
        );
        if best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((arch.label(), t));
        }
    }

    // Spectrum sanity: the two injected tones dominate.
    let (name, t) = best.unwrap();
    println!("\nfastest memory: {name} at {t:.2} us (paper: \"the 16 bank memory, with the");
    println!("complex bank mapping, typically gives us the highest performance\")");

    let mut mags: Vec<(usize, f64)> = hr
        .iter()
        .zip(&hi)
        .enumerate()
        .map(|(k, (r, i))| (k, (r * r + i * i).sqrt()))
        .collect();
    mags.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\ntop spectral peaks (expect bins 13 and 201 + mirrors):");
    for (k, m) in mags.iter().take(4) {
        println!("  bin {k:>4}: |X| = {m:.1}");
    }
    assert!(mags[..4].iter().any(|(k, _)| *k == 13));
    assert!(mags[..4].iter().any(|(k, _)| *k == 201));
    println!("\nend-to-end pipeline verified ✓ (L1 Pallas == L2 JAX == L3 simulator)");
}
