//! Quickstart: build a machine, run one benchmark, read the paper-style
//! metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use soft_simt::prelude::*;

fn main() {
    // A 16-bank shared memory with the Offset (complex-data) mapping —
    // the configuration that wins Table III.
    let arch = MemoryArchKind::Banked { banks: 16, mapping: BankMapping::offset() };

    // Generate the 32x32 transpose program the paper benchmarks, then run
    // it on a machine with a random memory image.
    let program = transpose_program(32);
    println!(
        "program '{}': {} instructions, {} threads",
        program.name,
        program.insts.len(),
        program.threads
    );

    let mut machine = Machine::new(MachineConfig::for_arch(arch).with_mem_words(4096));
    let mut rng = soft_simt::util::XorShift64::new(1);
    let image: Vec<u32> = (0..1024).map(|_| rng.next_u32()).collect();
    machine.load_image(0, &image);

    let report = machine.run_program(&program).expect("runs");
    println!("total cycles : {}", report.total_cycles());
    println!("time         : {:.2} us @ {:.0} MHz", report.time_us(), arch.fmax_mhz());
    println!("load cycles  : {}", report.stats.d_load_cycles);
    println!("store cycles : {}", report.stats.store_cycles);
    if let Some(e) = report.r_bank_eff() {
        println!("R bank eff.  : {:.1}%", e * 100.0);
    }
    if let Some(e) = report.w_bank_eff() {
        println!("W bank eff.  : {:.1}%", e * 100.0);
    }

    // Check the result against a host transpose.
    let out = machine.read_image(1024, 1024);
    for i in 0..32 {
        for j in 0..32 {
            assert_eq!(out[j * 32 + i], image[i * 32 + j]);
        }
    }
    println!("transpose verified against host reference ✓");

    // The same cell through the coordinator (what the table renderers use).
    let result = BenchJob::new("transpose32", arch).run().unwrap();
    assert_eq!(result.report.total_cycles(), report.total_cycles());
    println!("coordinator cell agrees ✓");
}
