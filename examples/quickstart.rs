//! Quickstart: open a `SimtEngine` session, run benchmark cells through
//! typed requests, and watch the session's trace cache collapse the
//! cost of repeat work.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use soft_simt::prelude::*;
use soft_simt::service::wire;

fn main() {
    // One engine session: worker pool + persistent trace cache. Every
    // request below shares both.
    let engine = SimtEngine::new();

    // A 16-bank shared memory with the Offset (complex-data) mapping —
    // the configuration that wins Table III — running the 32x32
    // transpose the paper benchmarks.
    let arch = MemoryArchKind::Banked { banks: 16, mapping: BankMapping::offset() };
    let resp = engine
        .handle(&Request::Run { program: "transpose32".into(), mem: arch })
        .expect("runs");
    print!("{}", resp.render());

    // The same workload on every paper memory: the engine replays the
    // cached trace — one functional execution total, nine reports.
    for mem in MemoryArchKind::table3_nine() {
        let resp = engine
            .handle(&Request::Run { program: "transpose32".into(), mem })
            .expect("replays");
        let Response::Run(report) = resp else { unreachable!() };
        println!("{:18} {:>8} cycles", report.arch.label(), report.total_cycles());
    }
    assert_eq!(engine.functional_executions(), 1);
    println!("nine memories timed from one functional execution ✓");

    // Typed errors, one lineage: unknown names are usage errors (exit
    // code 2), simulator faults are execution errors (exit code 1).
    let err = engine
        .handle(&Request::Run { program: "quicksort".into(), mem: arch })
        .unwrap_err();
    println!("typed error: {err} (exit code {})", err.exit_code());

    // The wire codec the `soft-simt serve` transport speaks: requests
    // and responses are single JSON lines.
    let req = Request::Disasm { program: "transpose32".into() };
    println!("wire request : {}", wire::request_to_json(&req));
    let line = wire::response_to_json(&engine.handle(&req).unwrap());
    println!("wire response: {}...", &line[..line.len().min(72)]);

    // The advisor — the paper's §VII decision rule — through the same
    // session (its exploration reuses the cached transpose trace).
    let resp = engine
        .handle(&Request::Advise { program: "transpose32".into() })
        .expect("advises");
    let Response::Advise(advice) = &resp else { unreachable!() };
    println!(
        "advisor: fastest {} / best perf-per-area {}",
        advice.fastest().arch.label(),
        advice.most_efficient().arch.label()
    );
    assert_eq!(engine.functional_executions(), 1, "still one execution");
    println!("session cache shared across request types ✓");
}
